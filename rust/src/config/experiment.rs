//! Typed experiment configuration + named presets.
//!
//! Every paper table/figure is regenerated from a [`Preset`]; the launcher
//! (`lazygp run --preset table1`) and the benches both resolve through this
//! module so EXPERIMENTS.md numbers come from exactly one source of truth.

use super::json::{Json, JsonError};
use crate::acquisition::functions::AcquisitionKind;
use crate::acquisition::optim::OptimConfig;
use crate::bo::driver::{BoConfig, InitDesign};
use crate::gp::SurrogateSpec;
use crate::kernels::{Kernel, KernelKind, KernelParams};

/// A fully-specified experiment.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub objective: String,
    pub surrogate: SurrogateSpec,
    pub kernel_kind: KernelKind,
    pub kernel_params: KernelParams,
    pub acquisition: AcquisitionKind,
    pub init: InitDesign,
    pub iters: usize,
    pub seed: u64,
    /// parallel workers (1 = sequential; 20 = paper §4.4)
    pub workers: usize,
    pub optim: OptimConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "adhoc".into(),
            objective: "levy5".into(),
            surrogate: SurrogateSpec::Lazy { lag: 0 },
            kernel_kind: KernelKind::Matern52,
            kernel_params: KernelParams::paper_default(),
            acquisition: AcquisitionKind::paper_default(),
            init: InitDesign::Random(1),
            iters: 100,
            seed: 0,
            workers: 1,
            optim: OptimConfig::fast(),
        }
    }
}

impl ExperimentConfig {
    /// Convert to a [`BoConfig`] for the sequential driver.
    pub fn bo_config(&self) -> BoConfig {
        BoConfig {
            surrogate: self.surrogate,
            kernel: Kernel::new(self.kernel_kind, self.kernel_params),
            acquisition: self.acquisition,
            optim: self.optim.clone(),
            init: self.init,
            seed: self.seed,
            batch_min_dist: 0.05,
            parallelism: crate::util::parallel::Parallelism::default(),
            fit_grid: crate::gp::hyperfit::FitSpace::default().grid,
            batch_hedged: false,
        }
    }

    // ---------- JSON ----------

    pub fn to_json(&self) -> Json {
        let surrogate = self.surrogate.to_json();
        let acquisition = match self.acquisition {
            AcquisitionKind::Ei { xi } => Json::obj(vec![
                ("kind", Json::Str("ei".into())),
                ("xi", Json::Num(xi)),
            ]),
            AcquisitionKind::Pi { xi } => Json::obj(vec![
                ("kind", Json::Str("pi".into())),
                ("xi", Json::Num(xi)),
            ]),
            AcquisitionKind::Ucb { beta } => Json::obj(vec![
                ("kind", Json::Str("ucb".into())),
                ("beta", Json::Num(beta)),
            ]),
        };
        let init = match self.init {
            InitDesign::Random(n) => Json::obj(vec![
                ("kind", Json::Str("random".into())),
                ("n", Json::Num(n as f64)),
            ]),
            InitDesign::Lhs(n) => Json::obj(vec![
                ("kind", Json::Str("lhs".into())),
                ("n", Json::Num(n as f64)),
            ]),
        };
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("objective", Json::Str(self.objective.clone())),
            ("surrogate", surrogate),
            ("kernel", Json::obj(vec![
                ("kind", Json::Str(self.kernel_kind.name().into())),
                ("variance", Json::Num(self.kernel_params.variance)),
                ("length_scale", Json::Num(self.kernel_params.length_scale)),
                ("noise", Json::Num(self.kernel_params.noise)),
            ])),
            ("acquisition", acquisition),
            ("init", init),
            ("iters", Json::Num(self.iters as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("optim", Json::obj(vec![
                ("candidates", Json::Num(self.optim.candidates as f64)),
                ("restarts", Json::Num(self.optim.restarts as f64)),
                ("nm_iters", Json::Num(self.optim.nm_iters as f64)),
                ("nm_scale", Json::Num(self.optim.nm_scale)),
            ])),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut cfg = ExperimentConfig::default();
        let get_str = |j: &Json, k: &str| -> Option<String> {
            j.get(k).and_then(|v| v.as_str()).map(str::to_string)
        };
        if let Some(v) = get_str(j, "name") {
            cfg.name = v;
        }
        if let Some(v) = get_str(j, "objective") {
            cfg.objective = v;
        }
        if let Some(s) = j.get("surrogate") {
            cfg.surrogate = SurrogateSpec::from_json(s)?;
        }
        if let Some(k) = j.get("kernel") {
            if let Some(kind) = k.get("kind").and_then(|v| v.as_str()) {
                cfg.kernel_kind =
                    KernelKind::from_name(kind).ok_or_else(|| format!("bad kernel `{kind}`"))?;
            }
            if let Some(v) = k.get("variance").and_then(|v| v.as_f64()) {
                cfg.kernel_params.variance = v;
            }
            if let Some(v) = k.get("length_scale").and_then(|v| v.as_f64()) {
                cfg.kernel_params.length_scale = v;
            }
            if let Some(v) = k.get("noise").and_then(|v| v.as_f64()) {
                cfg.kernel_params.noise = v;
            }
        }
        if let Some(a) = j.get("acquisition") {
            cfg.acquisition = match a.get("kind").and_then(|v| v.as_str()) {
                Some("ei") => AcquisitionKind::Ei {
                    xi: a.get("xi").and_then(|v| v.as_f64()).unwrap_or(0.01),
                },
                Some("pi") => AcquisitionKind::Pi {
                    xi: a.get("xi").and_then(|v| v.as_f64()).unwrap_or(0.01),
                },
                Some("ucb") => AcquisitionKind::Ucb {
                    beta: a.get("beta").and_then(|v| v.as_f64()).unwrap_or(2.0),
                },
                other => return Err(format!("bad acquisition kind {other:?}")),
            };
        }
        if let Some(i) = j.get("init") {
            let n = i.get("n").and_then(|v| v.as_usize()).unwrap_or(1);
            cfg.init = match i.get("kind").and_then(|v| v.as_str()) {
                Some("random") | None => InitDesign::Random(n),
                Some("lhs") => InitDesign::Lhs(n),
                other => return Err(format!("bad init kind {other:?}")),
            };
        }
        if let Some(v) = j.get("iters").and_then(|v| v.as_usize()) {
            cfg.iters = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_u64()) {
            cfg.seed = v;
        }
        if let Some(v) = j.get("workers").and_then(|v| v.as_usize()) {
            cfg.workers = v;
        }
        if let Some(o) = j.get("optim") {
            if let Some(v) = o.get("candidates").and_then(|v| v.as_usize()) {
                cfg.optim.candidates = v;
            }
            if let Some(v) = o.get("restarts").and_then(|v| v.as_usize()) {
                cfg.optim.restarts = v;
            }
            if let Some(v) = o.get("nm_iters").and_then(|v| v.as_usize()) {
                cfg.optim.nm_iters = v;
            }
            if let Some(v) = o.get("nm_scale").and_then(|v| v.as_f64()) {
                cfg.optim.nm_scale = v;
            }
        }
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<Self, String> {
        let j = Json::parse(s).map_err(|e: JsonError| e.to_string())?;
        Self::from_json(&j)
    }
}

/// Named experiment presets, one per paper table/figure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// Fig. 5 setting: 5-D Levy, lazy vs naive Cholesky timing.
    Fig5,
    /// Fig. 6 setting: lag sweep on 5-D Levy, 200 seeds.
    Fig6,
    /// Tab. 1: 5-D Levy, 1 seed and 100 seeds, naive vs lazy.
    Table1,
    /// Tab. 2 / Fig. 1: LeNet/MNIST simulated HPO, 5 hyper-parameters.
    Table2,
    /// Tab. 3: ResNet32/CIFAR10 simulated HPO, sequential.
    Table3,
    /// Tab. 4: ResNet32/CIFAR10 simulated HPO, parallel (20 workers).
    Table4,
}

impl Preset {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "fig5" => Some(Preset::Fig5),
            "fig6" => Some(Preset::Fig6),
            "table1" => Some(Preset::Table1),
            "table2" | "fig1" => Some(Preset::Table2),
            "table3" => Some(Preset::Table3),
            "table4" => Some(Preset::Table4),
            _ => None,
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["fig5", "fig6", "table1", "table2", "table3", "table4"]
    }

    /// The lazy-arm config for this preset (the exact arm is derived by the
    /// bench harness by swapping `surrogate`).
    pub fn config(self) -> ExperimentConfig {
        match self {
            Preset::Fig5 => ExperimentConfig {
                name: "fig5".into(),
                objective: "levy5".into(),
                iters: 1000,
                init: InitDesign::Random(1),
                ..Default::default()
            },
            Preset::Fig6 => ExperimentConfig {
                name: "fig6".into(),
                objective: "levy5".into(),
                iters: 300,
                init: InitDesign::Lhs(200),
                surrogate: SurrogateSpec::Lazy { lag: 3 },
                ..Default::default()
            },
            Preset::Table1 => ExperimentConfig {
                name: "table1".into(),
                objective: "levy5".into(),
                iters: 1000,
                init: InitDesign::Random(1),
                ..Default::default()
            },
            Preset::Table2 => ExperimentConfig {
                name: "table2".into(),
                objective: "lenet_mnist".into(),
                iters: 1000,
                init: InitDesign::Random(1),
                ..Default::default()
            },
            Preset::Table3 => ExperimentConfig {
                name: "table3".into(),
                objective: "resnet_cifar10".into(),
                iters: 300,
                init: InitDesign::Random(1),
                ..Default::default()
            },
            Preset::Table4 => ExperimentConfig {
                name: "table4".into(),
                objective: "resnet_cifar10".into(),
                iters: 300,
                init: InitDesign::Random(1),
                workers: 20,
                ..Default::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_default() {
        let cfg = ExperimentConfig::default();
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.objective, cfg.objective);
        assert_eq!(back.surrogate, cfg.surrogate);
        assert_eq!(back.kernel_kind, cfg.kernel_kind);
        assert_eq!(back.iters, cfg.iters);
        assert_eq!(back.workers, cfg.workers);
    }

    #[test]
    fn json_roundtrip_exotic() {
        let cfg = ExperimentConfig {
            name: "x".into(),
            objective: "hartmann6".into(),
            surrogate: SurrogateSpec::Lazy { lag: 7 },
            kernel_kind: KernelKind::Rbf,
            kernel_params: KernelParams { variance: 2.0, length_scale: 0.5, noise: 1e-4 },
            acquisition: AcquisitionKind::Ucb { beta: 3.0 },
            init: InitDesign::Lhs(50),
            iters: 77,
            seed: 12345,
            workers: 4,
            optim: OptimConfig { candidates: 99, restarts: 9, nm_iters: 11, nm_scale: 0.3 },
        };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.surrogate, SurrogateSpec::Lazy { lag: 7 });
        assert_eq!(back.kernel_kind, KernelKind::Rbf);
        assert_eq!(back.kernel_params.noise, 1e-4);
        assert_eq!(back.acquisition, AcquisitionKind::Ucb { beta: 3.0 });
        assert_eq!(back.init, InitDesign::Lhs(50));
        assert_eq!(back.optim.candidates, 99);
        assert_eq!(back.seed, 12345);
    }

    #[test]
    fn bad_json_rejected() {
        assert!(ExperimentConfig::from_json_str("{").is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"surrogate":{"kind":"wat"}}"#).is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"kernel":{"kind":"wat"}}"#).is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"acquisition":{"kind":"wat"}}"#).is_err());
    }

    #[test]
    fn presets_resolve() {
        for name in Preset::names() {
            let p = Preset::from_name(name).unwrap();
            let cfg = p.config();
            assert!(crate::objectives::by_name(&cfg.objective).is_some(), "{name}");
            assert!(cfg.iters > 0);
        }
        assert_eq!(Preset::from_name("fig1"), Some(Preset::Table2));
        assert!(Preset::from_name("nope").is_none());
    }

    #[test]
    fn table4_is_parallel() {
        assert_eq!(Preset::Table4.config().workers, 20);
        assert_eq!(Preset::Table3.config().workers, 1);
    }

    #[test]
    fn bo_config_reflects_choice() {
        let mut cfg = Preset::Table1.config();
        cfg.surrogate = SurrogateSpec::Exact;
        assert_eq!(cfg.bo_config().surrogate, SurrogateSpec::Exact);
    }

    #[test]
    fn json_roundtrip_dngo() {
        let cfg = ExperimentConfig {
            surrogate: SurrogateSpec::Dngo { rff_dim: 96 },
            ..Default::default()
        };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.surrogate, SurrogateSpec::Dngo { rff_dim: 96 });
    }

    #[test]
    fn missing_surrogate_defaults_to_lazy() {
        let back = ExperimentConfig::from_json_str(r#"{"objective":"levy5"}"#).unwrap();
        assert_eq!(back.surrogate, SurrogateSpec::Lazy { lag: 0 });
    }
}
