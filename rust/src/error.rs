//! Crate-wide error type — the offline stand-in for `anyhow`.
//!
//! A single string-backed error is enough for this crate: every fallible
//! path either bubbles an I/O error, a parse error with its own message, or
//! a hand-written context string. The [`err!`](crate::err!) and
//! [`bail!`](crate::bail!) macros mirror the `anyhow!`/`bail!` ergonomics
//! the launcher and runtime layers use.

use std::fmt;

/// String-backed error carrying a rendered message.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    /// Build from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<crate::config::json::JsonError> for Error {
    fn from(e: crate::config::json::JsonError) -> Self {
        Error(e.to_string())
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Self {
        Error(e.0)
    }
}

/// Build a [`Error`] from a format string (the `anyhow!` shape).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

/// Early-return an [`Error`] from a format string (the `bail!` shape).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::Error::msg(format!($($t)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert_eq!(format!("{e:#}"), "boom"); // alternate form used by main
    }

    #[test]
    fn converts_from_io_and_strings() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        let e: Error = String::from("x").into();
        assert_eq!(e.to_string(), "x");
    }

    #[test]
    fn macros_format() {
        fn fails() -> crate::Result<()> {
            bail!("bad {}", 7)
        }
        assert_eq!(fails().unwrap_err().to_string(), "bad 7");
        assert_eq!(err!("v={}", 1.5).to_string(), "v=1.5");
    }
}
