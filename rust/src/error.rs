//! Crate-wide error type — the offline stand-in for `anyhow`.
//!
//! A string-backed message covers most fallible paths (I/O, parsing,
//! hand-written context), mirrored by the [`err!`](crate::err!) and
//! [`bail!`](crate::bail!) macros. Two conditions the distributed
//! transport must let callers *match on* are typed variants instead of
//! prose:
//!
//! * [`Error::Protocol`] — a wire-protocol violation (corrupted or
//!   oversized frame, failed checksum, malformed or out-of-order message).
//!   A peer producing these is broken or hostile; the link is dropped, not
//!   retried.
//! * [`Error::AllWorkersLost`] — a remote transport's blocking receive
//!   observed zero live worker links for the configured deadline while
//!   outcomes were still expected. Pre-hardening this wedged the leader
//!   forever; now the coordinator surfaces it and the operator decides.
//! * [`Error::Journal`] — a durability journal is unusable beyond the
//!   torn-tail repairs recovery performs silently: a CRC-valid record with
//!   a malformed schema, a replay that contradicts the live RNG stream, or
//!   a snapshot/journal pair that disagree. Truncation damage never lands
//!   here — it is healed by design; this variant means the bytes lie.

use std::fmt;
use std::time::Duration;

/// Crate-wide error: a rendered message, or one of the typed transport
/// conditions callers dispatch on.
#[derive(Debug)]
pub enum Error {
    /// Generic rendered message (the `anyhow` analogue).
    Msg(String),
    /// Wire-protocol violation: corrupt/oversized frame, checksum
    /// mismatch, malformed or out-of-order message.
    Protocol(String),
    /// Every worker link of a remote transport is gone: no outcome and no
    /// live worker for `deadline` while work was still outstanding.
    AllWorkersLost {
        /// how long the transport waited with zero live links before
        /// giving up
        deadline: Duration,
    },
    /// A durability journal or snapshot is semantically corrupt — not a
    /// torn tail (those are truncated away during recovery) but bytes that
    /// passed the CRC yet cannot be honored: malformed record schema,
    /// replay/RNG divergence, snapshot–journal disagreement.
    Journal(String),
}

impl Error {
    /// Build a generic error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error::Msg(m.to_string())
    }

    /// Build a wire-protocol violation.
    pub fn protocol(m: impl fmt::Display) -> Self {
        Error::Protocol(m.to_string())
    }

    /// Is this a wire-protocol violation?
    pub fn is_protocol(&self) -> bool {
        matches!(self, Error::Protocol(_))
    }

    /// Is this the all-worker-links-lost condition?
    pub fn is_all_workers_lost(&self) -> bool {
        matches!(self, Error::AllWorkersLost { .. })
    }

    /// Build a journal-corruption error.
    pub fn journal(m: impl fmt::Display) -> Self {
        Error::Journal(m.to_string())
    }

    /// Is this a journal-corruption condition?
    pub fn is_journal(&self) -> bool {
        matches!(self, Error::Journal(_))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Msg(m) => f.write_str(m),
            Error::Protocol(m) => write!(f, "wire protocol violation: {m}"),
            Error::AllWorkersLost { deadline } => write!(
                f,
                "all worker links lost: no outcome and zero live workers for {:.1}s \
                 (workers rejoin with `lazygp worker --connect <leader>`)",
                deadline.as_secs_f64()
            ),
            Error::Journal(m) => write!(f, "journal corrupt: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::Msg(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Msg(e.to_string())
    }
}

impl From<crate::config::json::JsonError> for Error {
    fn from(e: crate::config::json::JsonError) -> Self {
        Error::Msg(e.to_string())
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Self {
        Error::Msg(e.0)
    }
}

/// Build a [`Error`] from a format string (the `anyhow!` shape).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

/// Early-return an [`Error`] from a format string (the `bail!` shape).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::Error::msg(format!($($t)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert_eq!(format!("{e:#}"), "boom"); // alternate form used by main
    }

    #[test]
    fn converts_from_io_and_strings() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        let e: Error = String::from("x").into();
        assert_eq!(e.to_string(), "x");
    }

    #[test]
    fn macros_format() {
        fn fails() -> crate::Result<()> {
            bail!("bad {}", 7)
        }
        assert_eq!(fails().unwrap_err().to_string(), "bad 7");
        assert_eq!(err!("v={}", 1.5).to_string(), "v=1.5");
    }

    #[test]
    fn typed_variants_classify_and_render() {
        let p = Error::protocol("checksum mismatch");
        assert!(p.is_protocol() && !p.is_all_workers_lost());
        assert!(p.to_string().contains("wire protocol violation"));
        assert!(p.to_string().contains("checksum mismatch"));

        let lost = Error::AllWorkersLost { deadline: Duration::from_secs(60) };
        assert!(lost.is_all_workers_lost() && !lost.is_protocol());
        assert!(lost.to_string().contains("60.0s"), "{lost}");

        let j = Error::journal("rng stream diverged at outcome 3");
        assert!(j.is_journal() && !j.is_protocol() && !j.is_all_workers_lost());
        assert!(j.to_string().contains("journal corrupt"));
        assert!(j.to_string().contains("diverged"));

        assert!(!Error::msg("plain").is_protocol());
        assert!(!Error::msg("plain").is_journal());
    }
}
