//! Incremental Cholesky extension — the paper's **Algorithm 3** and the
//! core of the "lazy Gaussian process".
//!
//! When the kernel hyper-parameters are frozen, adding a sample only
//! *borders* the covariance matrix:
//!
//! ```text
//! K_{n+1} = [ K_n  p ]        L_{n+1} = [ L_n  0 ]
//!           [ pᵀ   c ]                  [ qᵀ   d ]
//! ```
//!
//! with `L_n q = p` (forward substitution, `O(n²)`) and
//! `d = √(c − qᵀq)` (`O(n)`). The paper's Lemma (via Sylvester's inertia
//! theorem) guarantees `c − qᵀq > 0` whenever `K_{n+1}` is SPD; in floating
//! point a near-duplicate sample can still drive it to ≤ 0, which we guard
//! with a jitter floor and surface through [`ExtendStats`].
//!
//! [`GrowingCholesky`] owns a factor that grows in place with amortized
//! `O(n)` memory movement per appended row (capacity doubling over a flat
//! packed buffer), giving the `t·O(n²)` synchronization step of §3.4.

use super::matrix::{dot, Matrix};
use super::cholesky::{cholesky_in_place, CholeskyError};

/// Telemetry of incremental extensions; the metrics layer reports
/// near-singular clamps so experiments can verify the Lemma's assumption
/// held (it does for all paper workloads thanks to the σ² noise term).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExtendStats {
    /// total rows appended incrementally
    pub extensions: u64,
    /// times `c − qᵀq` fell below the jitter floor and was clamped
    pub clamped: u64,
}

/// A Cholesky factor that grows one (or `t`) bordered rows at a time.
///
/// Storage is *packed row-major lower-triangular*: row `i` occupies
/// `i+1` doubles. Growing by one row appends `n+1` doubles — no O(n²)
/// copy, unlike keeping a dense square matrix. (This single layout choice
/// is worth ~30% at n≈2000; see EXPERIMENTS.md §Perf.)
#[derive(Debug, Clone)]
pub struct GrowingCholesky {
    /// packed lower-triangular data
    data: Vec<f64>,
    /// current dimension n
    n: usize,
    /// floor for d² when an extension is numerically non-PD
    jitter: f64,
    stats: ExtendStats,
    /// scratch for the forward-substitution solve (avoids per-call alloc)
    scratch: Vec<f64>,
}

impl GrowingCholesky {
    /// Default jitter floor for clamped extensions (`d ≥ √jitter`).
    pub const DEFAULT_JITTER: f64 = 1e-10;

    /// Empty factor (n = 0).
    pub fn new() -> Self {
        Self::with_jitter(Self::DEFAULT_JITTER)
    }

    pub fn with_jitter(jitter: f64) -> Self {
        assert!(jitter > 0.0);
        Self { data: Vec::new(), n: 0, jitter, stats: ExtendStats::default(), scratch: Vec::new() }
    }

    /// Build by fully factoring an SPD matrix (paper Alg. 3, first branch:
    /// the one full `O(n³)` factorization at start-up / lag boundary).
    pub fn from_spd(k: &Matrix) -> Result<Self, CholeskyError> {
        let mut l = k.clone();
        cholesky_in_place(&mut l)?;
        Ok(Self::from_factor(&l))
    }

    /// [`from_spd`](Self::from_spd) with the factorization's sub-panel solve
    /// and trailing update distributed over the worker pool
    /// ([`crate::linalg::cholesky::cholesky_in_place_with`]). Bitwise
    /// identical to the serial build for every `par`; small matrices stay
    /// serial regardless.
    pub fn from_spd_with(
        k: &Matrix,
        par: crate::util::parallel::Parallelism,
    ) -> Result<Self, CholeskyError> {
        let n = k.rows();
        let threads = par.workers_for(n.saturating_mul(n).saturating_mul(n) / 3);
        let mut l = k.clone();
        crate::linalg::cholesky::cholesky_in_place_with(&mut l, threads)?;
        Ok(Self::from_factor(&l))
    }

    /// Adopt an existing dense lower-triangular factor.
    pub fn from_factor(l: &Matrix) -> Self {
        assert!(l.is_square());
        let n = l.rows();
        let mut data = Vec::with_capacity(n * (n + 1) / 2);
        for i in 0..n {
            data.extend_from_slice(&l.row(i)[..=i]);
        }
        Self {
            data,
            n,
            jitter: Self::DEFAULT_JITTER,
            stats: ExtendStats::default(),
            scratch: Vec::new(),
        }
    }

    /// Current dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn stats(&self) -> ExtendStats {
        self.stats
    }

    /// Seed the telemetry counters (used when a fresh factor replaces an
    /// old one at a lag boundary so cumulative stats survive).
    pub fn carry_stats(&mut self, stats: ExtendStats) {
        self.stats = stats;
    }

    /// Packed row `i` (length `i+1`).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.n);
        let off = i * (i + 1) / 2;
        &self.data[off..off + i + 1]
    }

    #[inline]
    pub fn diag(&self, i: usize) -> f64 {
        self.row(i)[i]
    }

    /// Element access (`j ≤ i`; entries above the diagonal are implicitly 0).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if j > i {
            0.0
        } else {
            self.row(i)[j]
        }
    }

    /// Paper **Alg. 3** lines 8–13: extend the factor with the border
    /// column `p` (covariances of the new point against the existing `n`)
    /// and diagonal `c` (its self-covariance + noise).
    ///
    /// `O(n²)` time, `O(n)` appended memory. Returns the new diagonal `d`.
    pub fn extend(&mut self, p: &[f64], c: f64) -> f64 {
        assert_eq!(p.len(), self.n, "extend: p must have length n");
        // forward substitution L q = p against the packed rows
        let n = self.n;
        self.scratch.clear();
        self.scratch.resize(n, 0.0);
        // move scratch out to sidestep the borrow of self.row()
        let mut q = std::mem::take(&mut self.scratch);
        for i in 0..n {
            let off = i * (i + 1) / 2;
            let row = &self.data[off..off + i + 1];
            let s = p[i] - dot(&row[..i], &q[..i]);
            q[i] = s / row[i];
        }
        let mut d2 = c - dot(&q, &q);
        if !(d2 > self.jitter) {
            // near-duplicate sample or accumulated round-off: clamp.
            self.stats.clamped += 1;
            d2 = self.jitter;
        }
        let d = d2.sqrt();
        self.data.reserve(n + 1);
        self.data.extend_from_slice(&q);
        self.data.push(d);
        self.scratch = q; // return the allocation for reuse
        self.n += 1;
        self.stats.extensions += 1;
        d
    }

    /// §3.4 synchronization: extend by `t` new points at once. Rows are
    /// appended sequentially (each new point's border `p_k` must include its
    /// covariances against the points appended before it in this batch), so
    /// the cost is `t·O(n²)` exactly as the paper states.
    ///
    /// `borders[k] = (p_k, c_k)` where `p_k.len() == n + k`.
    pub fn extend_batch(&mut self, borders: &[(Vec<f64>, f64)]) {
        for (k, (p, c)) in borders.iter().enumerate() {
            assert_eq!(p.len(), self.n, "extend_batch: border {k} has wrong length");
            self.extend(p, *c);
        }
    }

    /// Truncate the factor back to its leading `n × n` block.
    ///
    /// Because the storage is packed row-major and [`extend`] only
    /// *appends*, the leading block's bytes are untouched by any number of
    /// later extensions — so truncation is an exact, `O(1)` rollback of
    /// speculative extends (no recomputation, no round-off). This is what
    /// makes fantasy observations cheap for the async coordinator: dense
    /// square layouts would have to re-copy or re-factorize.
    ///
    /// ```
    /// use lazygp::linalg::GrowingCholesky;
    ///
    /// let mut f = GrowingCholesky::new();
    /// f.extend(&[], 4.0);       // 1×1 factor: L = [2]
    /// f.extend(&[2.0], 5.0);    // bordered to 2×2
    /// let before = f.to_dense();
    /// f.extend(&[1.0, 1.0], 6.0); // speculative third row…
    /// f.truncate(2);              // …rolled back bitwise in O(1)
    /// assert_eq!(f.dim(), 2);
    /// assert_eq!(f.to_dense().as_slice(), before.as_slice());
    /// ```
    ///
    /// Telemetry counters are *not* rewound (extensions that happened,
    /// happened); callers that snapshot-and-restore stats around a
    /// speculation window can pair this with [`carry_stats`].
    ///
    /// [`extend`]: GrowingCholesky::extend
    /// [`carry_stats`]: GrowingCholesky::carry_stats
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.n, "truncate({n}) beyond current dimension {}", self.n);
        self.data.truncate(n * (n + 1) / 2);
        self.n = n;
    }

    /// Forward substitution `L x = b` against the packed factor.
    ///
    /// The per-element operation order here is a **contract**: the refit
    /// engine's scratch-buffer solve (`gp::refit::eval_lml_cached`) and
    /// `linalg::triangular::solve_lower` mirror it exactly so their LML
    /// values stay bitwise equal to `gp::hyperfit::lml_centered`'s; the
    /// property suite pins the equality, so changing the reduction order
    /// here requires changing it there too.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let mut x = vec![0.0; self.n];
        for i in 0..self.n {
            let row = self.row(i);
            let s = b[i] - dot(&row[..i], &x[..i]);
            x[i] = s / row[i];
        }
        x
    }

    /// Backward substitution `Lᵀ x = b`.
    ///
    /// Same op-order contract as [`solve_lower`](Self::solve_lower): the
    /// refit engine mirrors this loop on its scratch buffers.
    pub fn solve_lower_transpose(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let mut x = b.to_vec();
        for i in (0..self.n).rev() {
            let row = self.row(i);
            let xi = x[i] / row[i];
            x[i] = xi;
            if xi != 0.0 {
                for j in 0..i {
                    x[j] -= row[j] * xi;
                }
            }
        }
        x
    }

    /// `K⁻¹ b` via the two triangular solves (Alg. 1 line 3).
    pub fn solve_spd(&self, b: &[f64]) -> Vec<f64> {
        self.solve_lower_transpose(&self.solve_lower(b))
    }

    /// Multi-RHS forward substitution `L X = B` (`B` is `n × m`, column
    /// `k` an independent RHS). Row-blocked over the packed factor so each
    /// `L` row streams once across all RHS columns — the batched-candidate
    /// scoring hot path (§Perf: ~4× over per-candidate solves at n=500,
    /// m=256).
    pub fn solve_lower_multi(&self, b: &Matrix) -> Matrix {
        assert_eq!(b.rows(), self.n, "solve_lower_multi shape");
        let m = b.cols();
        let mut x = b.clone();
        for i in 0..self.n {
            let off = i * (i + 1) / 2;
            // split x's storage so row i is mutable while rows <i are read
            let (solved, rest) = x.as_mut_slice().split_at_mut(i * m);
            let xi = &mut rest[..m];
            let lrow = &self.data[off..off + i + 1];
            for (k, &lik) in lrow[..i].iter().enumerate() {
                if lik != 0.0 {
                    let xk = &solved[k * m..(k + 1) * m];
                    for c in 0..m {
                        xi[c] -= lik * xk[c];
                    }
                }
            }
            let inv = 1.0 / lrow[i];
            for v in xi.iter_mut() {
                *v *= inv;
            }
        }
        x
    }

    /// Column-blocked, optionally multi-threaded multi-RHS forward
    /// substitution over the packed factor: `B`'s columns are split into
    /// tiles of `block_cols`, each tile solved on a contiguous scratch
    /// buffer, tiles distributed over `threads` scoped workers. Per-column
    /// operation order is identical to [`solve_lower_multi`], so the result
    /// is **bitwise identical** for every `threads`/`block_cols`.
    ///
    /// [`solve_lower_multi`]: GrowingCholesky::solve_lower_multi
    pub fn solve_lower_multi_blocked(
        &self,
        b: &Matrix,
        threads: usize,
        block_cols: usize,
    ) -> Matrix {
        assert_eq!(b.rows(), self.n, "solve_lower_multi shape");
        assert!(block_cols > 0, "solve_lower_multi_blocked: block_cols must be > 0");
        let n = self.n;
        let m = b.cols();
        if n == 0 || m == 0 {
            return b.clone();
        }
        let nblocks = m.div_ceil(block_cols);
        let mut blocks: Vec<Vec<f64>> = vec![Vec::new(); nblocks];
        crate::util::parallel::for_each_chunk_mut(&mut blocks, 1, threads, |bi, slot| {
            let c0 = bi * block_cols;
            let bw = block_cols.min(m - c0);
            let mut x = vec![0.0; n * bw];
            for i in 0..n {
                x[i * bw..(i + 1) * bw].copy_from_slice(&b.row(i)[c0..c0 + bw]);
            }
            for i in 0..n {
                let off = i * (i + 1) / 2;
                let lrow = &self.data[off..off + i + 1];
                let (solved, rest) = x.split_at_mut(i * bw);
                let xi = &mut rest[..bw];
                for (k, &lik) in lrow[..i].iter().enumerate() {
                    if lik != 0.0 {
                        let xk = &solved[k * bw..(k + 1) * bw];
                        for c in 0..bw {
                            xi[c] -= lik * xk[c];
                        }
                    }
                }
                let inv = 1.0 / lrow[i];
                for v in xi.iter_mut() {
                    *v *= inv;
                }
            }
            slot[0] = x;
        });
        let mut out = Matrix::zeros(n, m);
        for (bi, x) in blocks.iter().enumerate() {
            let c0 = bi * block_cols;
            let bw = block_cols.min(m - c0);
            for i in 0..n {
                out.row_mut(i)[c0..c0 + bw].copy_from_slice(&x[i * bw..(i + 1) * bw]);
            }
        }
        out
    }

    /// `Σ log L_ii` (Alg. 1 line 7 term).
    pub fn sum_log_diag(&self) -> f64 {
        (0..self.n).map(|i| self.diag(i).ln()).sum()
    }

    /// Materialize as a dense lower-triangular [`Matrix`] (tests, runtime
    /// artifact inputs).
    pub fn to_dense(&self) -> Matrix {
        let n = self.n;
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.row_mut(i)[..=i].copy_from_slice(self.row(i));
        }
        m
    }

    /// Reconstruct `K = L Lᵀ` (verification helper).
    pub fn reconstruct(&self) -> Matrix {
        self.to_dense().llt()
    }
}

impl Default for GrowingCholesky {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky;
    use crate::util::proptest as pt;
    use crate::util::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let a = Matrix::from_fn(n, n, |_, _| rng.uniform(-1.0, 1.0));
        let mut spd = a.matmul(&a.transpose());
        for i in 0..n {
            spd[(i, i)] += n as f64 + 1.0;
        }
        spd
    }

    /// THE invariant of the paper: growing K row-by-row incrementally gives
    /// exactly the factor a full factorization of the final K gives.
    #[test]
    fn incremental_equals_full() {
        let mut rng = Pcg64::new(41);
        for &n in &[2, 5, 12, 40, 75] {
            let k = random_spd(&mut rng, n);
            // full factorization of the complete matrix
            let l_full = cholesky(&k).unwrap();
            // incremental: start from the 1x1 leading block, extend n-1 times
            let mut g = GrowingCholesky::new();
            g.extend(&[], k[(0, 0)]);
            for m in 1..n {
                let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
                g.extend(&p, k[(m, m)]);
            }
            let l_inc = g.to_dense();
            let diff = l_inc.max_abs_diff(&l_full);
            assert!(diff < 1e-9, "n={n} diff={diff:e}");
            assert_eq!(g.stats().clamped, 0);
        }
    }

    #[test]
    fn from_spd_then_extend_matches_full() {
        let mut rng = Pcg64::new(43);
        let n0 = 20;
        let add = 15;
        let n = n0 + add;
        let k = random_spd(&mut rng, n);
        let k0 = Matrix::from_fn(n0, n0, |i, j| k[(i, j)]);
        let mut g = GrowingCholesky::from_spd(&k0).unwrap();
        for m in n0..n {
            let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
            g.extend(&p, k[(m, m)]);
        }
        let l_full = cholesky(&k).unwrap();
        assert!(g.to_dense().max_abs_diff(&l_full) < 1e-9);
    }

    #[test]
    fn extend_batch_matches_sequential() {
        let mut rng = Pcg64::new(45);
        let n0 = 10;
        let t = 5;
        let k = random_spd(&mut rng, n0 + t);
        let k0 = Matrix::from_fn(n0, n0, |i, j| k[(i, j)]);
        let mut a = GrowingCholesky::from_spd(&k0).unwrap();
        let mut b = a.clone();
        let borders: Vec<(Vec<f64>, f64)> = (n0..n0 + t)
            .map(|m| ((0..m).map(|i| k[(m, i)]).collect(), k[(m, m)]))
            .collect();
        for (p, c) in &borders {
            a.extend(p, *c);
        }
        b.extend_batch(&borders);
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-15);
    }

    #[test]
    fn near_duplicate_clamps_not_nan() {
        // two identical points: K is singular up to the noise term; with
        // zero noise the extension must clamp, not produce NaN
        let k00 = 1.0;
        let mut g = GrowingCholesky::new();
        g.extend(&[], k00);
        let d = g.extend(&[1.0], 1.0); // duplicate ⇒ c − qᵀq = 0
        assert!(d > 0.0 && d.is_finite());
        assert_eq!(g.stats().clamped, 1);
        // factor still usable
        let x = g.solve_spd(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn solve_spd_matches_dense_solves() {
        let mut rng = Pcg64::new(47);
        let n = 30;
        let k = random_spd(&mut rng, n);
        let g = GrowingCholesky::from_spd(&k).unwrap();
        let y: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let alpha = g.solve_spd(&y);
        let r = k.matvec(&alpha);
        for i in 0..n {
            assert!((r[i] - y[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn packed_blocked_multi_rhs_bitwise_matches_serial() {
        let mut rng = Pcg64::new(55);
        for &(n, m) in &[(1usize, 3usize), (17, 9), (40, 100)] {
            let k = random_spd(&mut rng, n);
            let g = GrowingCholesky::from_spd(&k).unwrap();
            let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
            let serial = g.solve_lower_multi(&b);
            for threads in [1, 2, 4] {
                for block in [1, 7, 64, 128] {
                    let blocked = g.solve_lower_multi_blocked(&b, threads, block);
                    let same = serial
                        .as_slice()
                        .iter()
                        .zip(blocked.as_slice())
                        .all(|(a, c)| a.to_bits() == c.to_bits());
                    assert!(same, "n={n} m={m} threads={threads} block={block}");
                }
            }
        }
    }

    #[test]
    fn from_spd_with_bitwise_matches_serial_build() {
        let mut rng = Pcg64::new(57);
        for &n in &[10usize, 97, 150] {
            let k = random_spd(&mut rng, n);
            let serial = GrowingCholesky::from_spd(&k).unwrap();
            for par in [
                crate::util::parallel::Parallelism::Serial,
                crate::util::parallel::Parallelism::Threads(4),
            ] {
                let g = GrowingCholesky::from_spd_with(&k, par).unwrap();
                assert_eq!(g.dim(), serial.dim());
                for i in 0..n {
                    for (a, b) in g.row(i).iter().zip(serial.row(i)) {
                        assert_eq!(a.to_bits(), b.to_bits(), "n={n} row {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn sum_log_diag_matches_logdet() {
        let mut rng = Pcg64::new(49);
        let n = 15;
        let k = random_spd(&mut rng, n);
        let g = GrowingCholesky::from_spd(&k).unwrap();
        let l = cholesky(&k).unwrap();
        let want: f64 = (0..n).map(|i| l[(i, i)].ln()).sum();
        assert!((g.sum_log_diag() - want).abs() < 1e-10);
    }

    #[test]
    fn packed_layout_accessors() {
        let l = Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 1.0, 3.0, 0.0, 0.5, 0.25, 4.0]);
        let g = GrowingCholesky::from_factor(&l);
        assert_eq!(g.dim(), 3);
        assert_eq!(g.get(0, 0), 2.0);
        assert_eq!(g.get(2, 1), 0.25);
        assert_eq!(g.get(1, 2), 0.0); // above diagonal
        assert_eq!(g.diag(2), 4.0);
        assert_eq!(g.row(1), &[1.0, 3.0]);
        assert_eq!(g.to_dense(), l);
    }

    #[test]
    fn reconstruct_roundtrip() {
        let mut rng = Pcg64::new(51);
        let k = random_spd(&mut rng, 22);
        let g = GrowingCholesky::from_spd(&k).unwrap();
        let rel = g.reconstruct().max_abs_diff(&k) / k.fro_norm();
        assert!(rel < 1e-12);
    }

    #[test]
    fn truncate_rolls_back_extends_bitwise() {
        let mut rng = Pcg64::new(53);
        let n0 = 12;
        let add = 6;
        let k = random_spd(&mut rng, n0 + add);
        let k0 = Matrix::from_fn(n0, n0, |i, j| k[(i, j)]);
        let mut g = GrowingCholesky::from_spd(&k0).unwrap();
        let before_data: Vec<f64> = (0..n0).flat_map(|i| g.row(i).to_vec()).collect();
        let before_stats = g.stats();
        for m in n0..n0 + add {
            let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
            g.extend(&p, k[(m, m)]);
        }
        assert_eq!(g.dim(), n0 + add);
        g.truncate(n0);
        g.carry_stats(before_stats);
        assert_eq!(g.dim(), n0);
        let after_data: Vec<f64> = (0..n0).flat_map(|i| g.row(i).to_vec()).collect();
        // bitwise identity, not approximate equality
        for (a, b) in before_data.iter().zip(&after_data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(g.stats(), before_stats);
        // the factor is fully usable afterwards: extend again and match a
        // from-scratch factorization
        for m in n0..n0 + add {
            let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
            g.extend(&p, k[(m, m)]);
        }
        let l_full = cholesky(&k).unwrap();
        assert!(g.to_dense().max_abs_diff(&l_full) < 1e-9);
    }

    #[test]
    fn truncate_to_zero_and_regrow() {
        let mut g = GrowingCholesky::new();
        g.extend(&[], 4.0);
        g.extend(&[1.0], 5.0);
        g.truncate(0);
        assert!(g.is_empty());
        g.extend(&[], 9.0);
        assert_eq!(g.diag(0), 3.0);
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_beyond_dim_panics() {
        let mut g = GrowingCholesky::new();
        g.extend(&[], 1.0);
        g.truncate(2);
    }

    #[test]
    fn prop_incremental_equals_full_random_sizes() {
        let sizes = pt::usize_in(1, 35);
        pt::check("incremental_vs_full", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 4000);
            let k = random_spd(&mut rng, n);
            let l_full = cholesky(&k).unwrap();
            let mut g = GrowingCholesky::new();
            g.extend(&[], k[(0, 0)]);
            for m in 1..n {
                let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
                g.extend(&p, k[(m, m)]);
            }
            g.to_dense().max_abs_diff(&l_full) < 1e-8
        });
    }

    #[test]
    fn prop_solve_is_inverse_action() {
        let sizes = pt::usize_in(1, 30);
        pt::check("growing_solve_spd", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 5000);
            let k = random_spd(&mut rng, n);
            let g = GrowingCholesky::from_spd(&k).unwrap();
            let y: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let r = k.matvec(&g.solve_spd(&y));
            r.iter().zip(&y).all(|(a, b)| (a - b).abs() < 1e-7)
        });
    }

    #[test]
    fn prop_diag_stays_positive() {
        let sizes = pt::usize_in(2, 30);
        pt::check("growing_diag_positive", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 6000);
            let k = random_spd(&mut rng, n);
            let mut g = GrowingCholesky::new();
            g.extend(&[], k[(0, 0)]);
            for m in 1..n {
                let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
                g.extend(&p, k[(m, m)]);
            }
            (0..n).all(|i| g.diag(i) > 0.0)
        });
    }
}
