//! Row-major dense matrix.
//!
//! Deliberately minimal: the GP stack needs symmetric assembly, matvec,
//! dot products, and slicing of contiguous rows — not a general BLAS. The
//! storage is a single `Vec<f64>` so Cholesky factors can grow in place
//! with amortized-constant row appends (see [`crate::linalg::incremental`]).

use std::fmt;

/// Row-major dense `rows × cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity of size `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two disjoint rows, `i < j`, borrowed simultaneously (needed by the
    /// in-place factorization inner loops).
    #[inline]
    pub fn two_rows_mut(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        debug_assert!(i < j && j < self.rows);
        let (a, b) = self.data.split_at_mut(j * self.cols);
        (&mut a[i * self.cols..(i + 1) * self.cols], &mut b[..self.cols])
    }

    /// Raw storage (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw storage vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Matrix–vector product `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec shape");
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// Transposed matrix–vector product `y = Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t shape");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                for (yj, &aij) in y.iter_mut().zip(self.row(i)) {
                    *yj += xi * aij;
                }
            }
        }
        y
    }

    /// Dense product `C = A B` (small sizes only; used by tests and the
    /// posterior covariance of batched predictions).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape");
        let mut c = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik != 0.0 {
                    let brow = b.row(k);
                    let crow = c.row_mut(i);
                    for j in 0..brow.len() {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        c
    }

    /// Transpose copy.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Append a row (the matrix must stay rectangular).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "push_row width");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Grow a square matrix by one row *and* one column, placing `col` in
    /// the new column (first `n` entries), `row` in the new row, and `corner`
    /// at the new diagonal. Used to grow covariance matrices in place.
    pub fn grow_square(&mut self, row: &[f64], col: &[f64], corner: f64) {
        assert!(self.is_square());
        let n = self.rows;
        assert_eq!(row.len(), n);
        assert_eq!(col.len(), n);
        let mut data = Vec::with_capacity((n + 1) * (n + 1));
        for i in 0..n {
            data.extend_from_slice(self.row(i));
            data.push(col[i]);
        }
        data.extend_from_slice(row);
        data.push(corner);
        self.rows = n + 1;
        self.cols = n + 1;
        self.data = data;
    }

    /// Max absolute element-wise difference to another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Is the matrix symmetric to tolerance `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if !self.is_square() {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// `L Lᵀ` for a lower-triangular `L` (verification helper).
    pub fn llt(&self) -> Matrix {
        assert!(self.is_square());
        let n = self.rows;
        Matrix::from_fn(n, n, |i, j| {
            let m = i.min(j);
            (0..=m).map(|k| self[(i, k)] * self[(j, k)]).sum()
        })
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  [")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// Dot product with 4-way unrolled accumulation (helps the triangular-solve
/// hot loop; see EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// `y ← y + alpha * x` (axpy).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_from_fn() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn identity_matvec() {
        let m = Matrix::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn matvec_known() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.matvec(&[1.0, 1.0, 1.0]), vec![6.0, 15.0]);
        assert_eq!(m.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(3, 3, |i, j| (i + j) as f64);
        let i3 = Matrix::identity(3);
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn grow_square_layout() {
        let mut m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 5.0]);
        m.grow_square(&[7.0, 8.0], &[7.0, 8.0], 9.0);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.as_slice(), &[1.0, 2.0, 7.0, 2.0, 5.0, 8.0, 7.0, 8.0, 9.0]);
        assert!(m.is_symmetric(0.0));
    }

    #[test]
    fn push_row_grows() {
        let mut m = Matrix::zeros(1, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn two_rows_mut_disjoint() {
        let mut m = Matrix::from_fn(3, 2, |i, _| i as f64);
        let (a, b) = m.two_rows_mut(0, 2);
        a[0] = 100.0;
        b[1] = 200.0;
        assert_eq!(m[(0, 0)], 100.0);
        assert_eq!(m[(2, 1)], 200.0);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (13 - i) as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn llt_of_identity() {
        let l = Matrix::identity(5);
        assert_eq!(l.llt(), Matrix::identity(5));
    }

    #[test]
    fn symmetry_check() {
        let s = Matrix::from_fn(4, 4, |i, j| (i + j) as f64);
        assert!(s.is_symmetric(0.0));
        let mut a = s.clone();
        a[(0, 1)] += 1.0;
        assert!(!a.is_symmetric(1e-9));
    }

    #[test]
    fn axpy_adds() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }
}
