//! Dense linear-algebra substrate.
//!
//! Everything the paper's GP inference needs, built from scratch:
//!
//! * [`matrix`] — a row-major dense [`matrix::Matrix`] with the small set of
//!   BLAS-level operations the GP uses (symmetric assembly, matvec, dot).
//! * [`cholesky`] — the full factorization (paper **Alg. 2**), in both the
//!   textbook form and a cache-blocked right-looking form used after the
//!   performance pass.
//! * [`triangular`] — forward/backward substitution, single and multi-RHS.
//! * [`incremental`] — the paper's contribution (**Alg. 3**): `O(n²)`
//!   extension of an existing Cholesky factor by one or more rows, plus the
//!   growable [`incremental::GrowingCholesky`] state used by `gp::LazyGp`
//!   and the coordinator's synchronization step.

pub mod cholesky;
pub mod incremental;
pub mod matrix;
pub mod triangular;

pub use cholesky::{cholesky_in_place, CholeskyError};
pub use incremental::GrowingCholesky;
pub use matrix::Matrix;
pub use triangular::{solve_lower, solve_lower_transpose, solve_upper};
