//! Triangular solves.
//!
//! Forward substitution `L q = p` is the inner loop of the paper's Alg. 3
//! (the `O(n²)` step that replaces the `O(n³)` refactorization), and the
//! pair of solves `L α' = y`, `Lᵀ α = α'` implements Alg. 1 line 3.
//!
//! The multi-RHS variants additionally come in *column-blocked* forms
//! ([`solve_lower_multi_blocked`], [`solve_lower_transpose_multi_blocked`]):
//! the RHS columns are split into tiles of [`SOLVE_BLOCK_COLS`], each tile
//! is solved on a contiguous scratch buffer (so every `L` row streams once
//! per tile instead of once per column), and tiles run on the scoped
//! worker pool. RHS columns are independent systems and each column's
//! per-element operation order is unchanged, so the blocked/threaded
//! results are **bitwise identical** to the serial reference for every
//! thread count and block width.

use super::matrix::{dot, Matrix};
use crate::util::parallel::{for_each_chunk_mut, Parallelism};

/// RHS columns per solve tile: 64 columns of f64 keep a scratch row (512 B)
/// within one cache line burst and the whole tile (n × 64 doubles) inside
/// L2 for the state sizes the acquisition path batches at.
pub const SOLVE_BLOCK_COLS: usize = 64;

/// Solve `L x = b` for lower-triangular `L` (forward substitution).
/// `O(n²)`. Panics on shape mismatch; division by a zero diagonal yields
/// `inf`/`nan` which the GP layer guards against upstream (jitter floor).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    assert!(l.is_square());
    let n = l.rows();
    assert_eq!(b.len(), n, "solve_lower shape");
    let mut x = vec![0.0; n];
    for i in 0..n {
        let row = l.row(i);
        let s = b[i] - dot(&row[..i], &x[..i]);
        x[i] = s / row[i];
    }
    x
}

/// Solve `Lᵀ x = b` for lower-triangular `L` (backward substitution over
/// the transpose, without materializing it). `O(n²)`.
pub fn solve_lower_transpose(l: &Matrix, b: &[f64]) -> Vec<f64> {
    assert!(l.is_square());
    let n = l.rows();
    assert_eq!(b.len(), n, "solve_lower_transpose shape");
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let xi = x[i] / l[(i, i)];
        x[i] = xi;
        if xi != 0.0 {
            // eliminate x[i] from the remaining equations: column i of Lᵀ
            // is row i of L
            for j in 0..i {
                x[j] -= l[(i, j)] * xi;
            }
        }
    }
    x
}

/// Solve `U x = b` for upper-triangular `U` (backward substitution).
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    assert!(u.is_square());
    let n = u.rows();
    assert_eq!(b.len(), n, "solve_upper shape");
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let row = u.row(i);
        let s = b[i] - dot(&row[i + 1..], &x[i + 1..]);
        x[i] = s / row[i];
    }
    x
}

/// Multi-RHS forward substitution: solve `L X = B` where the `k`-th RHS is
/// `B` column `k`. `B` is `n × m`, returned `X` is `n × m`. Column-blocked
/// to keep `L` rows hot in cache — this is the hot path of batched
/// candidate scoring (posterior variance needs `v = L⁻¹ k*` per candidate).
pub fn solve_lower_multi(l: &Matrix, b: &Matrix) -> Matrix {
    assert!(l.is_square());
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_multi shape");
    let m = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let lrow = l.row(i).to_vec(); // copy to sidestep aliasing on x rows
        let diag = lrow[i];
        for k in 0..i {
            let lik = lrow[k];
            if lik != 0.0 {
                let (xk, xi) = x.two_rows_mut(k, i);
                for c in 0..m {
                    xi[c] -= lik * xk[c];
                }
            }
        }
        let xi = x.row_mut(i);
        for c in 0..m {
            xi[c] /= diag;
        }
    }
    x
}

/// Column-blocked, optionally multi-threaded multi-RHS forward
/// substitution. Splits `B`'s columns into tiles of `block_cols`, solves
/// each tile on a contiguous `n × bw` scratch buffer, and distributes the
/// tiles over `threads` scoped workers. Bitwise identical to
/// [`solve_lower_multi`] for every `threads`/`block_cols`.
pub fn solve_lower_multi_blocked(
    l: &Matrix,
    b: &Matrix,
    threads: usize,
    block_cols: usize,
) -> Matrix {
    assert!(l.is_square());
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_multi shape");
    assert!(block_cols > 0, "solve_lower_multi_blocked: block_cols must be > 0");
    let m = b.cols();
    if n == 0 || m == 0 {
        return b.clone();
    }
    let nblocks = m.div_ceil(block_cols);
    let mut blocks: Vec<Vec<f64>> = vec![Vec::new(); nblocks];
    for_each_chunk_mut(&mut blocks, 1, threads, |bi, slot| {
        let c0 = bi * block_cols;
        let bw = block_cols.min(m - c0);
        let mut x = vec![0.0; n * bw];
        for i in 0..n {
            x[i * bw..(i + 1) * bw].copy_from_slice(&b.row(i)[c0..c0 + bw]);
        }
        for i in 0..n {
            let lrow = l.row(i);
            let (solved, rest) = x.split_at_mut(i * bw);
            let xi = &mut rest[..bw];
            for (k, &lik) in lrow[..i].iter().enumerate() {
                if lik != 0.0 {
                    let xk = &solved[k * bw..(k + 1) * bw];
                    for c in 0..bw {
                        xi[c] -= lik * xk[c];
                    }
                }
            }
            let diag = lrow[i];
            for v in xi.iter_mut() {
                *v /= diag;
            }
        }
        slot[0] = x;
    });
    assemble_blocks(n, m, block_cols, &blocks)
}

/// Multi-RHS backward substitution `Lᵀ X = B` over the non-transposed
/// factor (serial reference; column `k` of `B` an independent RHS).
pub fn solve_lower_transpose_multi(l: &Matrix, b: &Matrix) -> Matrix {
    assert!(l.is_square());
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_transpose_multi shape");
    let m = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lrow = l.row(i).to_vec(); // copy to sidestep aliasing on x rows
        let diag = lrow[i];
        {
            let xi = x.row_mut(i);
            for c in 0..m {
                xi[c] /= diag;
            }
        }
        for (j, &lij) in lrow[..i].iter().enumerate() {
            if lij != 0.0 {
                let (xj, xi) = x.two_rows_mut(j, i);
                for c in 0..m {
                    xj[c] -= lij * xi[c];
                }
            }
        }
    }
    x
}

/// Column-blocked, optionally multi-threaded multi-RHS backward
/// substitution. Bitwise identical to [`solve_lower_transpose_multi`] for
/// every `threads`/`block_cols`.
pub fn solve_lower_transpose_multi_blocked(
    l: &Matrix,
    b: &Matrix,
    threads: usize,
    block_cols: usize,
) -> Matrix {
    assert!(l.is_square());
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_transpose_multi shape");
    assert!(block_cols > 0, "solve_lower_transpose_multi_blocked: block_cols must be > 0");
    let m = b.cols();
    if n == 0 || m == 0 {
        return b.clone();
    }
    let nblocks = m.div_ceil(block_cols);
    let mut blocks: Vec<Vec<f64>> = vec![Vec::new(); nblocks];
    for_each_chunk_mut(&mut blocks, 1, threads, |bi, slot| {
        let c0 = bi * block_cols;
        let bw = block_cols.min(m - c0);
        let mut x = vec![0.0; n * bw];
        for i in 0..n {
            x[i * bw..(i + 1) * bw].copy_from_slice(&b.row(i)[c0..c0 + bw]);
        }
        for i in (0..n).rev() {
            let lrow = l.row(i);
            let diag = lrow[i];
            let (head, rest) = x.split_at_mut(i * bw);
            let xi = &mut rest[..bw];
            for v in xi.iter_mut() {
                *v /= diag;
            }
            for (j, &lij) in lrow[..i].iter().enumerate() {
                if lij != 0.0 {
                    let xj = &mut head[j * bw..(j + 1) * bw];
                    for c in 0..bw {
                        xj[c] -= lij * xi[c];
                    }
                }
            }
        }
        slot[0] = x;
    });
    assemble_blocks(n, m, block_cols, &blocks)
}

/// Gather per-tile `n × bw` scratch buffers back into an `n × m` matrix.
fn assemble_blocks(n: usize, m: usize, block_cols: usize, blocks: &[Vec<f64>]) -> Matrix {
    let mut out = Matrix::zeros(n, m);
    for (bi, x) in blocks.iter().enumerate() {
        let c0 = bi * block_cols;
        let bw = block_cols.min(m - c0);
        for i in 0..n {
            out.row_mut(i)[c0..c0 + bw].copy_from_slice(&x[i * bw..(i + 1) * bw]);
        }
    }
    out
}

/// [`solve_lower_multi`] with the [`Parallelism`] knob: picks the worker
/// count from the `O(n² m)` solve work and the default block width.
pub fn solve_lower_multi_with(l: &Matrix, b: &Matrix, par: Parallelism) -> Matrix {
    let n = l.rows();
    let m = b.cols();
    let threads = par.workers_for(n * n * m / 2);
    solve_lower_multi_blocked(l, b, threads, SOLVE_BLOCK_COLS)
}

/// Invert a lower-triangular matrix (used only by small verification code
/// paths and tests — never in the hot loop).
pub fn invert_lower(l: &Matrix) -> Matrix {
    assert!(l.is_square());
    let n = l.rows();
    let mut inv = Matrix::zeros(n, n);
    for col in 0..n {
        let mut e = vec![0.0; n];
        e[col] = 1.0;
        let x = solve_lower(l, &e);
        for i in 0..n {
            inv[(i, col)] = x[i];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::cholesky;
    use crate::util::proptest as pt;
    use crate::util::rng::Pcg64;

    fn random_lower(rng: &mut Pcg64, n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if j < i {
                rng.uniform(-1.0, 1.0)
            } else if j == i {
                rng.uniform(0.5, 2.0) // well-conditioned diagonal
            } else {
                0.0
            }
        })
    }

    #[test]
    fn forward_solves_identity() {
        let l = Matrix::identity(5);
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(solve_lower(&l, &b), b);
    }

    #[test]
    fn forward_known_2x2() {
        let l = Matrix::from_vec(2, 2, vec![2.0, 0.0, 1.0, 3.0]);
        // L x = [4, 7] -> x0 = 2, x1 = (7-2)/3
        let x = solve_lower(&l, &[4.0, 7.0]);
        assert!((x[0] - 2.0).abs() < 1e-15);
        assert!((x[1] - 5.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn forward_residual_small() {
        let mut rng = Pcg64::new(21);
        for &n in &[1, 2, 9, 33, 120] {
            let l = random_lower(&mut rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let x = solve_lower(&l, &b);
            let r = l.matvec(&x);
            let err = r.iter().zip(&b).map(|(a, c)| (a - c).abs()).fold(0.0, f64::max);
            assert!(err < 1e-9, "n={n} err={err:e}");
        }
    }

    #[test]
    fn transpose_solve_residual_small() {
        let mut rng = Pcg64::new(23);
        for &n in &[1, 5, 40, 90] {
            let l = random_lower(&mut rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let x = solve_lower_transpose(&l, &b);
            let r = l.transpose().matvec(&x);
            let err = r.iter().zip(&b).map(|(a, c)| (a - c).abs()).fold(0.0, f64::max);
            assert!(err < 1e-9, "n={n} err={err:e}");
        }
    }

    #[test]
    fn upper_solve_residual_small() {
        let mut rng = Pcg64::new(25);
        let n = 30;
        let u = random_lower(&mut rng, n).transpose();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let x = solve_upper(&u, &b);
        let r = u.matvec(&x);
        let err = r.iter().zip(&b).map(|(a, c)| (a - c).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10);
    }

    #[test]
    fn multi_rhs_matches_single() {
        let mut rng = Pcg64::new(27);
        let n = 25;
        let m = 7;
        let l = random_lower(&mut rng, n);
        let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
        let x = solve_lower_multi(&l, &b);
        for col in 0..m {
            let bc: Vec<f64> = (0..n).map(|i| b[(i, col)]).collect();
            let xc = solve_lower(&l, &bc);
            for i in 0..n {
                assert!((x[(i, col)] - xc[i]).abs() < 1e-11, "col {col} row {i}");
            }
        }
    }

    #[test]
    fn blocked_multi_rhs_bitwise_matches_serial() {
        let mut rng = Pcg64::new(33);
        for &(n, m) in &[(1usize, 1usize), (13, 7), (40, 130), (25, 64)] {
            let l = random_lower(&mut rng, n);
            let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
            let serial = solve_lower_multi(&l, &b);
            for threads in [1, 2, 4] {
                for block in [1, 3, 64, 200] {
                    let blocked = solve_lower_multi_blocked(&l, &b, threads, block);
                    let same = serial
                        .as_slice()
                        .iter()
                        .zip(blocked.as_slice())
                        .all(|(a, c)| a.to_bits() == c.to_bits());
                    assert!(same, "n={n} m={m} threads={threads} block={block}");
                }
            }
            let with = solve_lower_multi_with(&l, &b, crate::util::parallel::Parallelism::Threads(3));
            assert_eq!(with.as_slice(), serial.as_slice());
        }
    }

    #[test]
    fn transpose_multi_rhs_matches_single_columns() {
        let mut rng = Pcg64::new(35);
        let n = 30;
        let m = 11;
        let l = random_lower(&mut rng, n);
        let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
        let x = solve_lower_transpose_multi(&l, &b);
        for col in 0..m {
            let bc: Vec<f64> = (0..n).map(|i| b[(i, col)]).collect();
            let xc = solve_lower_transpose(&l, &bc);
            for i in 0..n {
                assert!((x[(i, col)] - xc[i]).abs() < 1e-11, "col {col} row {i}");
            }
        }
        // blocked/threaded is bitwise vs the serial multi reference
        for threads in [2, 4] {
            for block in [2, 5, 64] {
                let blocked = solve_lower_transpose_multi_blocked(&l, &b, threads, block);
                let same = x
                    .as_slice()
                    .iter()
                    .zip(blocked.as_slice())
                    .all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "threads={threads} block={block}");
            }
        }
    }

    #[test]
    fn invert_lower_gives_inverse() {
        let mut rng = Pcg64::new(29);
        let n = 12;
        let l = random_lower(&mut rng, n);
        let inv = invert_lower(&l);
        let prod = l.matmul(&inv);
        assert!(prod.max_abs_diff(&Matrix::identity(n)) < 1e-9);
    }

    #[test]
    fn solve_pair_inverts_spd_system() {
        // combined forward+transpose solve = K^{-1} y via Cholesky
        let mut rng = Pcg64::new(31);
        let a = Matrix::from_fn(10, 10, |_, _| rng.uniform(-1.0, 1.0));
        let mut k = a.matmul(&a.transpose());
        for i in 0..10 {
            k[(i, i)] += 10.0;
        }
        let l = cholesky(&k).unwrap();
        let y: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let alpha = solve_lower_transpose(&l, &solve_lower(&l, &y));
        let r = k.matvec(&alpha);
        for i in 0..10 {
            assert!((r[i] - y[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn prop_forward_then_mul_roundtrips() {
        let sizes = pt::usize_in(1, 50);
        pt::check("tri_solve_roundtrip", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 3000);
            let l = random_lower(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b = l.matvec(&x_true);
            let x = solve_lower(&l, &b);
            x.iter().zip(&x_true).all(|(a, b)| (a - b).abs() < 1e-8)
        });
    }
}
