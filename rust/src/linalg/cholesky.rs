//! Full Cholesky factorization — the paper's **Algorithm 2**, i.e. the
//! `O(n³/3)` baseline that the lazy/incremental scheme (Alg. 3) replaces.
//!
//! Two implementations:
//!
//! * [`cholesky_unblocked`] — the textbook three-loop form, a direct
//!   transcription of the paper's Alg. 2 (kept as the reference and used by
//!   the naive-baseline benchmarks so Fig. 5 measures what the paper
//!   measured);
//! * [`cholesky_in_place`] — a cache-blocked right-looking variant (panel
//!   factorization + rank-k trailing update) that the performance pass
//!   selected for everything else. Identical output, ~4–6× faster at
//!   n ≳ 500 on this machine (see EXPERIMENTS.md §Perf).

use super::matrix::{dot, Matrix};

/// Failure modes of the factorization.
#[derive(Debug, PartialEq)]
pub enum CholeskyError {
    /// A diagonal pivot was ≤ 0: the matrix is not positive definite
    /// (within floating-point). Carries the failing pivot index.
    NotPositiveDefinite(usize),
    /// The input was not square.
    NotSquare(usize, usize),
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(i) => {
                write!(f, "matrix not positive definite at pivot {i}")
            }
            CholeskyError::NotSquare(r, c) => write!(f, "matrix is not square: {r}x{c}"),
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Paper **Alg. 2**: unblocked, in-place lower Cholesky.
///
/// On success `a` holds `L` in its lower triangle (upper triangle zeroed,
/// matching lines 13–17 of the paper's listing).
pub fn cholesky_unblocked(a: &mut Matrix) -> Result<(), CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    for i in 0..n {
        for j in 0..i {
            // K_ij -= sum_k K_ik K_jk ; K_ij /= K_jj
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= a[(i, k)] * a[(j, k)];
            }
            a[(i, j)] = s / a[(j, j)];
        }
        let mut d = a[(i, i)];
        for k in 0..i {
            d -= a[(i, k)] * a[(i, k)];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(CholeskyError::NotPositiveDefinite(i));
        }
        a[(i, i)] = d.sqrt();
        for j in (i + 1)..n {
            a[(i, j)] = 0.0;
        }
    }
    Ok(())
}

/// Block size for the right-looking factorization. 48×48 f64 panels
/// (~18 KiB) keep the panel plus one trailing tile comfortably inside L1/L2;
/// chosen empirically in the §Perf pass (32 and 64 were within 5%).
const BLOCK: usize = 48;

/// Cache-blocked, in-place lower Cholesky (right-looking).
///
/// Semantics identical to [`cholesky_unblocked`]; this is the production
/// path used by `ExactGp` refits and the lag-boundary refactorizations of
/// `LazyGp`.
pub fn cholesky_in_place(a: &mut Matrix) -> Result<(), CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    let mut k = 0;
    while k < n {
        let kb = BLOCK.min(n - k);
        // 1) factor the diagonal panel A[k..k+kb, k..k+kb] unblocked
        for i in k..k + kb {
            for j in k..i {
                let (rj, ri) = a.two_rows_mut(j, i);
                let s = ri[j] - dot(&ri[k..j], &rj[k..j]);
                ri[j] = s / rj[j];
            }
            let ri = a.row_mut(i);
            let d = ri[i] - dot(&ri[k..i], &ri[k..i]);
            if d <= 0.0 || !d.is_finite() {
                return Err(CholeskyError::NotPositiveDefinite(i));
            }
            ri[i] = d.sqrt();
        }
        // 2) solve the sub-panel: A[k+kb.., k..k+kb] ← A[..] L_panel^{-T}
        for i in k + kb..n {
            for j in k..k + kb {
                let (rj, ri) = a.two_rows_mut(j, i);
                let s = ri[j] - dot(&ri[k..j], &rj[k..j]);
                ri[j] = s / rj[j];
            }
        }
        // 3) trailing update: A[k+kb.., k+kb..] -= P Pᵀ (lower part only),
        //    where P = A[k+kb.., k..k+kb]
        for i in k + kb..n {
            for j in k + kb..=i {
                if i == j {
                    let ri = a.row_mut(i);
                    ri[i] -= dot(&ri[k..k + kb], &ri[k..k + kb]);
                } else {
                    let (rj, ri) = a.two_rows_mut(j, i);
                    ri[j] -= dot(&ri[k..k + kb], &rj[k..k + kb]);
                }
            }
        }
        k += kb;
    }
    // zero the upper triangle (paper Alg. 2 lines 13–17)
    for i in 0..n {
        let row = a.row_mut(i);
        for v in row[i + 1..].iter_mut() {
            *v = 0.0;
        }
    }
    Ok(())
}

/// Reusable scratch for [`cholesky_in_place_with_scratch`]: the factored
/// diagonal panel plus the post-solve sub-panel snapshot the parallel
/// trailing update reads from. Holding one of these per worker lets a hot
/// caller (the hyper-fit refit engine) factor repeatedly with **zero
/// allocations after warm-up**.
#[derive(Debug, Default)]
pub struct CholeskyScratch {
    /// factored diagonal panel, row-major `kb × kb`
    panel: Vec<f64>,
    /// sub-panel columns `k..k+kb` of the trailing rows, row-major
    pcols: Vec<f64>,
}

impl CholeskyScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Minimum dimension before [`cholesky_in_place_with`] engages the worker
/// pool; below two blocks the parallel bookkeeping outweighs the win.
const PAR_MIN_DIM: usize = 2 * BLOCK;

/// Trailing-update rows per job handed to the pool. Rows have heterogeneous
/// cost (row `i` updates `i − k − kb + 1` entries), so jobs stay small
/// enough for the work-stealing queue to balance them.
const PAR_ROWS_PER_JOB: usize = 8;

/// Multi-threaded variant of [`cholesky_in_place`]: the sub-panel solve and
/// the rank-`kb` trailing update distribute their (independent) rows over
/// `threads` scoped workers. Each element is produced by the serial path's
/// exact operation sequence — cross-row reads go through snapshots of
/// values that are final before the parallel step starts — so the result is
/// **bitwise identical** to [`cholesky_in_place`] for every `threads`.
/// `threads <= 1` (or a small matrix) falls through to the serial path.
pub fn cholesky_in_place_with(a: &mut Matrix, threads: usize) -> Result<(), CholeskyError> {
    let mut scratch = CholeskyScratch::new();
    cholesky_in_place_with_scratch(a, threads, &mut scratch)
}

/// [`cholesky_in_place_with`] with caller-owned scratch (no allocations
/// beyond the scratch's own warm-up growth).
pub fn cholesky_in_place_with_scratch(
    a: &mut Matrix,
    threads: usize,
    scratch: &mut CholeskyScratch,
) -> Result<(), CholeskyError> {
    if threads <= 1 || a.rows() < PAR_MIN_DIM {
        return cholesky_in_place(a);
    }
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    let mut k = 0;
    while k < n {
        let kb = BLOCK.min(n - k);
        // 1) factor the diagonal panel — serial, identical to the blocked
        //    reference (the panel is 48×48: no parallel win available)
        for i in k..k + kb {
            for j in k..i {
                let (rj, ri) = a.two_rows_mut(j, i);
                let s = ri[j] - dot(&ri[k..j], &rj[k..j]);
                ri[j] = s / rj[j];
            }
            let ri = a.row_mut(i);
            let d = ri[i] - dot(&ri[k..i], &ri[k..i]);
            if d <= 0.0 || !d.is_finite() {
                return Err(CholeskyError::NotPositiveDefinite(i));
            }
            ri[i] = d.sqrt();
        }
        let rest = n - k - kb;
        if rest > 0 {
            // snapshot the factored panel: cross-row reads in step 2 come
            // from here, so workers only write their own rows
            scratch.panel.resize(kb * kb, 0.0);
            for li in 0..kb {
                scratch.panel[li * kb..(li + 1) * kb].copy_from_slice(&a.row(k + li)[k..k + kb]);
            }
            // 2) sub-panel solve: rows are independent systems
            {
                let panel = &scratch.panel;
                let tail = &mut a.as_mut_slice()[(k + kb) * n..];
                crate::util::parallel::for_each_chunk_mut(
                    tail,
                    PAR_ROWS_PER_JOB * n,
                    threads,
                    |_, chunk| {
                        for row in chunk.chunks_mut(n) {
                            for j in k..k + kb {
                                let lj = j - k;
                                let prow = &panel[lj * kb..lj * kb + lj + 1];
                                let s = row[j] - dot(&row[k..j], &prow[..lj]);
                                row[j] = s / prow[lj];
                            }
                        }
                    },
                );
            }
            // snapshot P = the solved sub-panel columns: the trailing update
            // of row i reads rows j ≤ i, whose panel columns are final now
            scratch.pcols.resize(rest * kb, 0.0);
            for li in 0..rest {
                scratch.pcols[li * kb..(li + 1) * kb]
                    .copy_from_slice(&a.row(k + kb + li)[k..k + kb]);
            }
            // 3) trailing update A[k+kb.., k+kb..] -= P Pᵀ (lower part),
            //    rows independent via the P snapshot
            {
                let pcols = &scratch.pcols;
                let tail = &mut a.as_mut_slice()[(k + kb) * n..];
                crate::util::parallel::for_each_chunk_mut(
                    tail,
                    PAR_ROWS_PER_JOB * n,
                    threads,
                    |ci, chunk| {
                        for (local, row) in chunk.chunks_mut(n).enumerate() {
                            let li = ci * PAR_ROWS_PER_JOB + local;
                            let own = &pcols[li * kb..(li + 1) * kb];
                            for j in k + kb..=(k + kb + li) {
                                let lj = j - (k + kb);
                                row[j] -= dot(own, &pcols[lj * kb..(lj + 1) * kb]);
                            }
                        }
                    },
                );
            }
        }
        k += kb;
    }
    // zero the upper triangle (paper Alg. 2 lines 13–17)
    for i in 0..n {
        let row = a.row_mut(i);
        for v in row[i + 1..].iter_mut() {
            *v = 0.0;
        }
    }
    Ok(())
}

/// Convenience: factor a copy, returning `L`.
pub fn cholesky(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let mut l = a.clone();
    cholesky_in_place(&mut l)?;
    Ok(l)
}

/// Log-determinant of the factored matrix from its Cholesky factor:
/// `log det K = 2 Σ log L_ii` (paper Alg. 1 line 7 uses `Σ log L_ii`).
pub fn logdet_from_factor(l: &Matrix) -> f64 {
    (0..l.rows()).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Pcg64;

    /// Random SPD matrix `A Aᵀ + n·I`.
    pub(crate) fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let a = Matrix::from_fn(n, n, |_, _| rng.uniform(-1.0, 1.0));
        let mut spd = a.matmul(&a.transpose());
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    #[test]
    fn factors_known_3x3() {
        // classic example: A = [[4,12,-16],[12,37,-43],[-16,-43,98]]
        let a = Matrix::from_vec(
            3,
            3,
            vec![4.0, 12.0, -16.0, 12.0, 37.0, -43.0, -16.0, -43.0, 98.0],
        );
        let l = cholesky(&a).unwrap();
        let want = Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, -8.0, 5.0, 3.0]);
        assert!(l.max_abs_diff(&want) < 1e-12, "{l:?}");
    }

    #[test]
    fn unblocked_matches_blocked() {
        let mut rng = Pcg64::new(7);
        for &n in &[1, 2, 5, 17, 48, 49, 96, 131] {
            let a = random_spd(&mut rng, n);
            let mut u = a.clone();
            let mut b = a.clone();
            cholesky_unblocked(&mut u).unwrap();
            cholesky_in_place(&mut b).unwrap();
            assert!(u.max_abs_diff(&b) < 1e-9, "n={n} diff={}", u.max_abs_diff(&b));
        }
    }

    #[test]
    fn reconstructs_input() {
        let mut rng = Pcg64::new(9);
        for &n in &[3, 20, 60, 100] {
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            let rec = l.llt();
            let rel = rec.max_abs_diff(&a) / a.fro_norm();
            assert!(rel < 1e-12, "n={n} rel={rel:e}");
        }
    }

    #[test]
    fn rejects_non_spd() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert_eq!(cholesky(&a).unwrap_err(), CholeskyError::NotPositiveDefinite(1));
    }

    #[test]
    fn rejects_non_square() {
        let mut a = Matrix::zeros(2, 3);
        assert_eq!(cholesky_in_place(&mut a).unwrap_err(), CholeskyError::NotSquare(2, 3));
    }

    #[test]
    fn upper_triangle_zeroed() {
        let mut rng = Pcg64::new(11);
        let a = random_spd(&mut rng, 10);
        let l = cholesky(&a).unwrap();
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn logdet_matches_naive_2x2() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 1.0, 1.0, 2.0]); // det = 5
        let l = cholesky(&a).unwrap();
        assert!((logdet_from_factor(&l) - 5f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn parallel_bitwise_equals_serial() {
        let mut rng = Pcg64::new(13);
        // sizes straddling PAR_MIN_DIM and the 48-wide block boundaries
        for &n in &[5usize, 95, 96, 97, 131, 144, 200] {
            let a = random_spd(&mut rng, n);
            let mut serial = a.clone();
            cholesky_in_place(&mut serial).unwrap();
            let mut scratch = CholeskyScratch::new();
            for threads in [2usize, 3, 4] {
                let mut par = a.clone();
                cholesky_in_place_with_scratch(&mut par, threads, &mut scratch).unwrap();
                let same = serial
                    .as_slice()
                    .iter()
                    .zip(par.as_slice())
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_rejects_non_spd_like_serial() {
        let mut rng = Pcg64::new(15);
        let mut a = random_spd(&mut rng, 120);
        // poison a late pivot: make the trailing 2×2 block indefinite
        a[(119, 119)] = -1.0e6;
        let mut s = a.clone();
        let serial_err = cholesky_in_place(&mut s).unwrap_err();
        let mut p = a.clone();
        let par_err = cholesky_in_place_with(&mut p, 4).unwrap_err();
        assert_eq!(serial_err, par_err);
    }

    #[test]
    fn prop_factor_reconstructs_random_spd() {
        let sizes = pt::usize_in(1, 40);
        pt::check("cholesky_reconstructs", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 1000);
            let a = random_spd(&mut rng, n);
            let l = match cholesky(&a) {
                Ok(l) => l,
                Err(_) => return false,
            };
            let rel = l.llt().max_abs_diff(&a) / a.fro_norm().max(1.0);
            rel < 1e-11
        });
    }

    #[test]
    fn prop_diagonal_positive() {
        let sizes = pt::usize_in(1, 40);
        pt::check("cholesky_diag_positive", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 2000);
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            (0..n).all(|i| l[(i, i)] > 0.0)
        });
    }
}
