//! Full Cholesky factorization — the paper's **Algorithm 2**, i.e. the
//! `O(n³/3)` baseline that the lazy/incremental scheme (Alg. 3) replaces.
//!
//! Two implementations:
//!
//! * [`cholesky_unblocked`] — the textbook three-loop form, a direct
//!   transcription of the paper's Alg. 2 (kept as the reference and used by
//!   the naive-baseline benchmarks so Fig. 5 measures what the paper
//!   measured);
//! * [`cholesky_in_place`] — a cache-blocked right-looking variant (panel
//!   factorization + rank-k trailing update) that the performance pass
//!   selected for everything else. Identical output, ~4–6× faster at
//!   n ≳ 500 on this machine (see EXPERIMENTS.md §Perf).

use super::matrix::{dot, Matrix};

/// Failure modes of the factorization.
#[derive(Debug, PartialEq)]
pub enum CholeskyError {
    /// A diagonal pivot was ≤ 0: the matrix is not positive definite
    /// (within floating-point). Carries the failing pivot index.
    NotPositiveDefinite(usize),
    /// The input was not square.
    NotSquare(usize, usize),
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(i) => {
                write!(f, "matrix not positive definite at pivot {i}")
            }
            CholeskyError::NotSquare(r, c) => write!(f, "matrix is not square: {r}x{c}"),
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Paper **Alg. 2**: unblocked, in-place lower Cholesky.
///
/// On success `a` holds `L` in its lower triangle (upper triangle zeroed,
/// matching lines 13–17 of the paper's listing).
pub fn cholesky_unblocked(a: &mut Matrix) -> Result<(), CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    for i in 0..n {
        for j in 0..i {
            // K_ij -= sum_k K_ik K_jk ; K_ij /= K_jj
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= a[(i, k)] * a[(j, k)];
            }
            a[(i, j)] = s / a[(j, j)];
        }
        let mut d = a[(i, i)];
        for k in 0..i {
            d -= a[(i, k)] * a[(i, k)];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(CholeskyError::NotPositiveDefinite(i));
        }
        a[(i, i)] = d.sqrt();
        for j in (i + 1)..n {
            a[(i, j)] = 0.0;
        }
    }
    Ok(())
}

/// Block size for the right-looking factorization. 48×48 f64 panels
/// (~18 KiB) keep the panel plus one trailing tile comfortably inside L1/L2;
/// chosen empirically in the §Perf pass (32 and 64 were within 5%).
const BLOCK: usize = 48;

/// Cache-blocked, in-place lower Cholesky (right-looking).
///
/// Semantics identical to [`cholesky_unblocked`]; this is the production
/// path used by `ExactGp` refits and the lag-boundary refactorizations of
/// `LazyGp`.
pub fn cholesky_in_place(a: &mut Matrix) -> Result<(), CholeskyError> {
    if !a.is_square() {
        return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
    }
    let n = a.rows();
    let mut k = 0;
    while k < n {
        let kb = BLOCK.min(n - k);
        // 1) factor the diagonal panel A[k..k+kb, k..k+kb] unblocked
        for i in k..k + kb {
            for j in k..i {
                let (rj, ri) = a.two_rows_mut(j, i);
                let s = ri[j] - dot(&ri[k..j], &rj[k..j]);
                ri[j] = s / rj[j];
            }
            let ri = a.row_mut(i);
            let d = ri[i] - dot(&ri[k..i], &ri[k..i]);
            if d <= 0.0 || !d.is_finite() {
                return Err(CholeskyError::NotPositiveDefinite(i));
            }
            ri[i] = d.sqrt();
        }
        // 2) solve the sub-panel: A[k+kb.., k..k+kb] ← A[..] L_panel^{-T}
        for i in k + kb..n {
            for j in k..k + kb {
                let (rj, ri) = a.two_rows_mut(j, i);
                let s = ri[j] - dot(&ri[k..j], &rj[k..j]);
                ri[j] = s / rj[j];
            }
        }
        // 3) trailing update: A[k+kb.., k+kb..] -= P Pᵀ (lower part only),
        //    where P = A[k+kb.., k..k+kb]
        for i in k + kb..n {
            for j in k + kb..=i {
                if i == j {
                    let ri = a.row_mut(i);
                    ri[i] -= dot(&ri[k..k + kb], &ri[k..k + kb]);
                } else {
                    let (rj, ri) = a.two_rows_mut(j, i);
                    ri[j] -= dot(&ri[k..k + kb], &rj[k..k + kb]);
                }
            }
        }
        k += kb;
    }
    // zero the upper triangle (paper Alg. 2 lines 13–17)
    for i in 0..n {
        let row = a.row_mut(i);
        for v in row[i + 1..].iter_mut() {
            *v = 0.0;
        }
    }
    Ok(())
}

/// Convenience: factor a copy, returning `L`.
pub fn cholesky(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let mut l = a.clone();
    cholesky_in_place(&mut l)?;
    Ok(l)
}

/// Log-determinant of the factored matrix from its Cholesky factor:
/// `log det K = 2 Σ log L_ii` (paper Alg. 1 line 7 uses `Σ log L_ii`).
pub fn logdet_from_factor(l: &Matrix) -> f64 {
    (0..l.rows()).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Pcg64;

    /// Random SPD matrix `A Aᵀ + n·I`.
    pub(crate) fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let a = Matrix::from_fn(n, n, |_, _| rng.uniform(-1.0, 1.0));
        let mut spd = a.matmul(&a.transpose());
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    #[test]
    fn factors_known_3x3() {
        // classic example: A = [[4,12,-16],[12,37,-43],[-16,-43,98]]
        let a = Matrix::from_vec(
            3,
            3,
            vec![4.0, 12.0, -16.0, 12.0, 37.0, -43.0, -16.0, -43.0, 98.0],
        );
        let l = cholesky(&a).unwrap();
        let want = Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, -8.0, 5.0, 3.0]);
        assert!(l.max_abs_diff(&want) < 1e-12, "{l:?}");
    }

    #[test]
    fn unblocked_matches_blocked() {
        let mut rng = Pcg64::new(7);
        for &n in &[1, 2, 5, 17, 48, 49, 96, 131] {
            let a = random_spd(&mut rng, n);
            let mut u = a.clone();
            let mut b = a.clone();
            cholesky_unblocked(&mut u).unwrap();
            cholesky_in_place(&mut b).unwrap();
            assert!(u.max_abs_diff(&b) < 1e-9, "n={n} diff={}", u.max_abs_diff(&b));
        }
    }

    #[test]
    fn reconstructs_input() {
        let mut rng = Pcg64::new(9);
        for &n in &[3, 20, 60, 100] {
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            let rec = l.llt();
            let rel = rec.max_abs_diff(&a) / a.fro_norm();
            assert!(rel < 1e-12, "n={n} rel={rel:e}");
        }
    }

    #[test]
    fn rejects_non_spd() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert_eq!(cholesky(&a).unwrap_err(), CholeskyError::NotPositiveDefinite(1));
    }

    #[test]
    fn rejects_non_square() {
        let mut a = Matrix::zeros(2, 3);
        assert_eq!(cholesky_in_place(&mut a).unwrap_err(), CholeskyError::NotSquare(2, 3));
    }

    #[test]
    fn upper_triangle_zeroed() {
        let mut rng = Pcg64::new(11);
        let a = random_spd(&mut rng, 10);
        let l = cholesky(&a).unwrap();
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn logdet_matches_naive_2x2() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 1.0, 1.0, 2.0]); // det = 5
        let l = cholesky(&a).unwrap();
        assert!((logdet_from_factor(&l) - 5f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn prop_factor_reconstructs_random_spd() {
        let sizes = pt::usize_in(1, 40);
        pt::check("cholesky_reconstructs", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 1000);
            let a = random_spd(&mut rng, n);
            let l = match cholesky(&a) {
                Ok(l) => l,
                Err(_) => return false,
            };
            let rel = l.llt().max_abs_diff(&a) / a.fro_norm().max(1.0);
            rel < 1e-11
        });
    }

    #[test]
    fn prop_diagonal_positive() {
        let sizes = pt::usize_in(1, 40);
        pt::check("cholesky_diag_positive", &sizes, |&n| {
            let mut rng = Pcg64::new(n as u64 + 2000);
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            (0..n).all(|i| l[(i, i)] > 0.0)
        });
    }
}
