//! Cross-module property suite: randomized invariants that tie the layers
//! together, driven by the in-repo mini-proptest framework.

use lazygp::acquisition::functions::{AcquisitionFn, Ei};
use lazygp::bo::driver::{BoConfig, BoDriver, InitDesign, PendingStrategy};
use lazygp::config::json::Json;
use lazygp::gp::hyperfit::{fit_params_reference, FitSpace};
use lazygp::gp::lazy::LazyGp;
use lazygp::gp::posterior::{compute_alpha, Posterior};
use lazygp::gp::refit::RefitEngine;
use lazygp::gp::Surrogate;
use lazygp::kernels::cov::cov_matrix_tiled;
use lazygp::kernels::{cov_matrix, CovCache, Kernel, KernelKind, KernelParams};
use lazygp::linalg::triangular::{solve_lower_multi, solve_lower_multi_blocked};
use lazygp::linalg::{GrowingCholesky, Matrix};
use lazygp::objectives::levy::Levy;
use lazygp::util::parallel::Parallelism;
use lazygp::util::proptest as pt;
use lazygp::util::rng::Pcg64;
use lazygp::util::stats::{norm_cdf, norm_pdf};

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// JSON: serialize∘parse is the identity on randomly generated values.
#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let len = rng.below(12) as usize;
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let seeds = pt::usize_in(0, 10_000);
    pt::check("json_roundtrip", &seeds, |&seed| {
        let mut rng = Pcg64::new(seed as u64);
        let v = gen_value(&mut rng, 3);
        Json::parse(&v.to_string()) == Ok(v.clone())
            && Json::parse(&v.to_string_pretty()) == Ok(v)
    });
}

/// GP: posterior variance never exceeds the prior variance (in normalized
/// units, i.e. raw variance ≤ y_scale² · σ²), for any observation stream.
#[test]
fn prop_posterior_variance_bounded_by_prior() {
    let sizes = pt::usize_in(1, 40);
    pt::check("variance_bounded", &sizes, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9000);
        let mut gp = LazyGp::paper_default();
        for _ in 0..n {
            let x = vec![rng.uniform(-5.0, 5.0), rng.uniform(-5.0, 5.0)];
            gp.observe(&x, rng.uniform(-10.0, 10.0));
        }
        let prior = {
            let p = gp.posterior();
            p.y_scale * p.y_scale * p.kernel.self_cov()
        };
        (0..20).all(|_| {
            let q = vec![rng.uniform(-5.0, 5.0), rng.uniform(-5.0, 5.0)];
            let (_, v) = gp.predict(&q);
            v <= prior + 1e-9 && v >= 0.0
        })
    });
}

/// GP: batched prediction ≡ per-point prediction (the §Perf multi-RHS path
/// must be a pure optimization).
#[test]
fn prop_predict_batch_equals_predict() {
    let sizes = pt::usize_in(1, 30);
    pt::check("batch_equals_single", &sizes, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9100);
        let mut gp = LazyGp::paper_default();
        for _ in 0..n {
            let x: Vec<f64> = (0..3).map(|_| rng.uniform(-4.0, 4.0)).collect();
            gp.observe(&x, x.iter().sum::<f64>().cos());
        }
        let cands: Vec<Vec<f64>> =
            (0..17).map(|_| (0..3).map(|_| rng.uniform(-4.0, 4.0)).collect()).collect();
        let batched = gp.predict_batch(&cands);
        cands.iter().zip(&batched).all(|(c, &(bm, bv))| {
            let (m, v) = gp.predict(c);
            (m - bm).abs() < 1e-10 && (v - bv).abs() < 1e-10
        })
    });
}

/// EI: monotone in the mean, and equal to the closed form at hand-checked
/// points, for random incumbents.
#[test]
fn prop_ei_closed_form() {
    let g = pt::f64_in(-5.0, 5.0);
    pt::check("ei_closed_form", &g, |&best| {
        let acq = Ei { xi: 0.0 };
        let sigma: f64 = 1.7;
        (0..40).all(|i| {
            let mu = -6.0 + i as f64 * 0.3;
            let gamma = mu - best;
            let z = gamma / sigma;
            let want = (gamma * norm_cdf(z) + sigma * norm_pdf(z)).max(0.0);
            (acq.score(mu, sigma * sigma, best) - want).abs() < 1e-12
        })
    });
}

/// BO: the incumbent trajectory is monotone and history length is exact,
/// for random seeds and iteration budgets.
#[test]
fn prop_bo_incumbent_monotone() {
    let g = pt::usize_in(1, 15);
    pt::check("bo_monotone", &g, |&iters| {
        let cfg = BoConfig::lazy()
            .with_seed(iters as u64)
            .with_init(InitDesign::Random(2))
            .with_optim(lazygp::acquisition::optim::OptimConfig {
                candidates: 48,
                restarts: 2,
                nm_iters: 8,
                nm_scale: 0.1,
            });
        let mut d = BoDriver::new(cfg, Box::new(Levy::new(2)));
        d.run(iters);
        d.history().len() == iters + 2
            && d.history().windows(2).all(|w| w[1].best >= w[0].best)
    });
}

/// Cholesky: every kernel family produces an SPD covariance on random
/// (distinct) point sets — the precondition of the whole paper.
#[test]
fn prop_all_kernels_give_spd_covariance() {
    let g = pt::usize_in(2, 30);
    pt::check("kernels_spd", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9200);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..4).map(|_| rng.uniform(-8.0, 8.0)).collect()).collect();
        [KernelKind::Matern52, KernelKind::Matern32, KernelKind::Rbf, KernelKind::Exponential]
            .into_iter()
            .all(|kind| {
                let k = Kernel::new(kind, KernelParams::paper_default().with_noise(1e-8));
                GrowingCholesky::from_spd(&cov_matrix(&k, &xs)).is_ok()
            })
    });
}

/// Packed bits of a factor's leading `n × n` block.
fn factor_bits(g: &GrowingCholesky, n: usize) -> Vec<u64> {
    (0..n).flat_map(|i| g.row(i).iter().map(|v| v.to_bits()).collect::<Vec<_>>()).collect()
}

/// `GrowingCholesky::truncate` after `k` speculative extends restores the
/// untouched factor **bitwise** (0 ulp — the packed layout only appends),
/// with telemetry carried across the speculation window.
#[test]
fn prop_truncate_is_bitwise_rollback_of_extends() {
    let g = pt::usize_in(1, 30);
    pt::check("truncate_bitwise", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9400);
        let kernel = Kernel::paper_default();
        let extra = 1 + n % 5;
        let xs: Vec<Vec<f64>> = (0..n + extra)
            .map(|_| (0..3).map(|_| rng.uniform(-5.0, 5.0)).collect())
            .collect();
        let k = cov_matrix(&kernel, &xs);
        let k0 = Matrix::from_fn(n, n, |i, j| k[(i, j)]);
        let mut factor = match GrowingCholesky::from_spd(&k0) {
            Ok(f) => f,
            Err(_) => return false,
        };
        let bits_before = factor_bits(&factor, n);
        let stats_before = factor.stats();
        for m in n..n + extra {
            let p: Vec<f64> = (0..m).map(|i| k[(m, i)]).collect();
            factor.extend(&p, k[(m, m)]);
        }
        factor.truncate(n);
        factor.carry_stats(stats_before);
        factor.dim() == n
            && factor_bits(&factor, n) == bits_before
            && factor.stats() == stats_before
    });
}

/// Fantasy observe → rollback leaves the `LazyGp` posterior **bit-identical**
/// (packed factor bits, weights, normalization, length, predictions), for
/// every pending-imputation strategy.
#[test]
fn prop_lazy_fantasy_rollback_is_bitwise() {
    let g = pt::usize_in(1, 25);
    pt::check("fantasy_rollback_bitwise", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9500);
        let mut gp = LazyGp::paper_default();
        for _ in 0..n {
            let x = vec![rng.uniform(-4.0, 4.0), rng.uniform(-4.0, 4.0)];
            gp.observe(&x, x.iter().sum::<f64>().sin());
        }
        let probe = vec![rng.uniform(-4.0, 4.0), rng.uniform(-4.0, 4.0)];
        let snapshot = |gp: &LazyGp| {
            let p = gp.posterior();
            let (m, v) = gp.predict(&probe);
            (
                factor_bits(p.factor, p.factor.dim()),
                p.alpha.iter().map(|a| a.to_bits()).collect::<Vec<u64>>(),
                p.mean_offset.to_bits(),
                p.y_scale.to_bits(),
                gp.len(),
                m.to_bits(),
                v.to_bits(),
            )
        };
        let before = snapshot(&gp);
        let fantasies = 1 + n % 4;
        for _ in 0..fantasies {
            let x = vec![rng.uniform(-4.0, 4.0), rng.uniform(-4.0, 4.0)];
            gp.observe_fantasy(&x, rng.uniform(-2.0, 2.0));
        }
        if gp.len() != n + fantasies || gp.fantasies_active() != fantasies {
            return false;
        }
        let removed = gp.retract_fantasies();
        removed == fantasies && snapshot(&gp) == before && gp.fantasies_active() == 0
    });
}

/// The same bitwise-restore invariant holds when the fantasies are driven
/// through the BO driver's pending-strategy layer (the async coordinator's
/// actual code path).
#[test]
fn prop_driver_fantasize_retract_is_lossless() {
    let g = pt::usize_in(2, 12);
    pt::check("driver_fantasize_lossless", &g, |&n| {
        let cfg = BoConfig::lazy()
            .with_seed(n as u64)
            .with_init(InitDesign::Random(n))
            .with_optim(lazygp::acquisition::optim::OptimConfig {
                candidates: 32,
                restarts: 2,
                nm_iters: 5,
                nm_scale: 0.1,
            });
        let mut d = BoDriver::new(cfg, Box::new(Levy::new(2)));
        d.ensure_seeded();
        let mut rng = Pcg64::new(n as u64 + 9600);
        let pending: Vec<Vec<f64>> = (0..1 + n % 3)
            .map(|_| vec![rng.uniform(-10.0, 10.0), rng.uniform(-10.0, 10.0)])
            .collect();
        let probe = vec![rng.uniform(-10.0, 10.0), rng.uniform(-10.0, 10.0)];
        let before = {
            let (m, v) = d.surrogate().predict(&probe);
            (d.surrogate().len(), m.to_bits(), v.to_bits())
        };
        [
            PendingStrategy::ConstantLiarMin,
            PendingStrategy::PosteriorMean,
            PendingStrategy::KrigingBeliever,
        ]
        .into_iter()
        .all(|s| {
            let issued = d.fantasize(&pending, s);
            let grew = d.surrogate().len() == before.0 + pending.len();
            let retracted = d.retract_fantasies();
            let (m, v) = d.surrogate().predict(&probe);
            issued == pending.len()
                && grew
                && retracted == pending.len()
                && (d.surrogate().len(), m.to_bits(), v.to_bits()) == before
        })
    });
}

/// Tiled/multi-threaded covariance assembly is **bitwise identical** to the
/// serial reference for random sizes, dimensions, thread counts and tile
/// widths — parallelism only changes who computes, never what.
#[test]
fn prop_tiled_cov_assembly_bitwise() {
    let g = pt::usize_in(1, 60);
    pt::check("tiled_cov_bitwise", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9700);
        let d = 1 + n % 5;
        let kernel = Kernel::paper_default();
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform(-6.0, 6.0)).collect()).collect();
        let serial = cov_matrix(&kernel, &xs);
        let threads = 1 + (n % 4);
        let tile = 1 + (n % 37);
        let tiled = cov_matrix_tiled(&kernel, &xs, threads, tile);
        // the CovCache rebuild shares the same tile kernel + cached norms
        let mut cache = CovCache::new();
        for x in &xs {
            cache.push(x);
        }
        let via_cache = cache.full_cov_with(&kernel, Parallelism::Threads(threads));
        bits_eq(serial.as_slice(), tiled.as_slice())
            && bits_eq(serial.as_slice(), via_cache.as_slice())
    });
}

/// The parallel, distance-caching refit engine returns **bitwise
/// identical** fitted parameters to the naive serial hyper-fit loop,
/// across random data, thread counts ∈ {1, 2, 4} and grid sizes.
#[test]
fn prop_refit_engine_bitwise_matches_naive_loop() {
    let g = pt::usize_in(6, 22);
    pt::check("refit_engine_vs_naive", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9850);
        let d = 1 + n % 3;
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform(-4.0, 4.0)).collect()).collect();
        let y: Vec<f64> = xs.iter().map(|x| (x.iter().sum::<f64>() * 0.7).sin()).collect();
        let grid = 2 + n % 4; // 2..=5
        let space = FitSpace::default().with_grid(grid);
        let base = Kernel::paper_default();
        let want = fit_params_reference(&base, &xs, &y, &space);
        [1usize, 2, 4].iter().all(|&t| {
            let got = RefitEngine::one_shot(Parallelism::Threads(t)).fit(&base, &xs, &y, &space);
            got.length_scale.to_bits() == want.length_scale.to_bits()
                && got.variance.to_bits() == want.variance.to_bits()
                && got.noise.to_bits() == want.noise.to_bits()
        })
    });
}

/// A persistent (warm-starting) engine is thread-count deterministic: the
/// whole refit *sequence* — windows, fallbacks, refined optima — is
/// bitwise identical between serial and 4-thread engines.
#[test]
fn prop_warm_refit_sequence_thread_deterministic() {
    let g = pt::usize_in(8, 40);
    pt::check("warm_refit_thread_determinism", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9900);
        let base = Kernel::paper_default();
        let space = FitSpace::default();
        let mut serial = RefitEngine::new(Parallelism::Serial);
        let mut threaded = RefitEngine::new(Parallelism::Threads(4));
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut y: Vec<f64> = Vec::new();
        for _ in 0..3 {
            // grow the data between refits, like successive lag boundaries
            for _ in 0..n {
                let x = vec![rng.uniform(-4.0, 4.0), rng.uniform(-4.0, 4.0)];
                y.push((x[0] - 0.3 * x[1]).cos());
                xs.push(x);
            }
            let a = serial.fit(&base, &xs, &y, &space);
            let b = threaded.fit(&base, &xs, &y, &space);
            if a.length_scale.to_bits() != b.length_scale.to_bits()
                || a.variance.to_bits() != b.variance.to_bits()
            {
                return false;
            }
        }
        serial.stats() == threaded.stats()
    });
}

/// The batched border matrix is column-for-column bitwise identical to
/// per-point border vectors, for every thread count.
#[test]
fn prop_borders_batch_bitwise() {
    let g = pt::usize_in(1, 40);
    pt::check("borders_batch_bitwise", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9750);
        let d = 1 + n % 4;
        let kernel = Kernel::paper_default();
        let mut cache = CovCache::new();
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            cache.push(&x);
        }
        let m = 1 + n % 7;
        let queries: Vec<Vec<f64>> =
            (0..m).map(|_| (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect()).collect();
        let threads = 1 + n % 4;
        let kb = cache.borders_batch(&kernel, &queries, Parallelism::Threads(threads));
        queries.iter().enumerate().all(|(j, q)| {
            let col = cache.border(&kernel, q);
            (0..n).all(|i| kb[(i, j)].to_bits() == col[i].to_bits())
        })
    });
}

/// Blocked / multi-threaded multi-RHS forward substitution is bitwise
/// identical to the serial reference, over both the dense and the packed
/// factor, for random sizes, thread counts and block widths.
#[test]
fn prop_blocked_solves_bitwise() {
    let g = pt::usize_in(1, 45);
    pt::check("blocked_solves_bitwise", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9800);
        let kernel = Kernel::paper_default();
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..3).map(|_| rng.uniform(-5.0, 5.0)).collect()).collect();
        let k = cov_matrix(&kernel, &xs);
        let Ok(packed) = GrowingCholesky::from_spd(&k) else {
            return false;
        };
        let dense = packed.to_dense();
        let m = 1 + n % 9;
        let b = Matrix::from_fn(n, m, |_, _| rng.uniform(-2.0, 2.0));
        let threads = 1 + n % 4;
        let block = 1 + n % 13;
        let free_serial = solve_lower_multi(&dense, &b);
        let free_blocked = solve_lower_multi_blocked(&dense, &b, threads, block);
        let packed_serial = packed.solve_lower_multi(&b);
        let packed_blocked = packed.solve_lower_multi_blocked(&b, threads, block);
        bits_eq(free_serial.as_slice(), free_blocked.as_slice())
            && bits_eq(packed_serial.as_slice(), packed_blocked.as_slice())
    });
}

/// Tiled batched posterior scoring (means + variances) is bitwise identical
/// to the serial path for every thread count.
#[test]
fn prop_batched_posterior_scoring_bitwise() {
    let g = pt::usize_in(1, 35);
    pt::check("batched_posterior_bitwise", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9850);
        let kernel = Kernel::paper_default();
        let mut cache = CovCache::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let x: Vec<f64> = (0..3).map(|_| rng.uniform(-4.0, 4.0)).collect();
            ys.push(x.iter().sum::<f64>().cos());
            cache.push(&x);
        }
        let k = cache.full_cov(&kernel);
        let Ok(factor) = GrowingCholesky::from_spd(&k) else {
            return false;
        };
        let alpha = compute_alpha(&factor, &ys, 0.0, 1.0);
        let post =
            Posterior { factor: &factor, alpha: &alpha, mean_offset: 0.0, y_scale: 1.0, kernel };
        let m = 1 + n % 11;
        let cands: Vec<Vec<f64>> =
            (0..m).map(|_| (0..3).map(|_| rng.uniform(-4.0, 4.0)).collect()).collect();
        let kstar = cache.borders_batch(&kernel, &cands, Parallelism::Serial);
        let serial = post.predict_batch_from_borders_with(&kstar, Parallelism::Serial);
        let threads = 2 + n % 3;
        let tiled = post.predict_batch_from_borders_with(&kstar, Parallelism::Threads(threads));
        serial.len() == tiled.len()
            && serial.iter().zip(&tiled).all(|((ma, va), (mb, vb))| {
                ma.to_bits() == mb.to_bits() && va.to_bits() == vb.to_bits()
            })
    });
}

/// The grouped batched fantasy refresh (`Surrogate::observe_fantasies`) is
/// bitwise identical to a loop of single fantasy inserts, and the rollback
/// restores the pre-speculation posterior bitwise in both cases.
#[test]
fn prop_batched_fantasy_refresh_bitwise_rollback() {
    let g = pt::usize_in(1, 20);
    pt::check("batched_fantasy_bitwise", &g, |&n| {
        let build = |seed: u64| {
            let mut gp = LazyGp::paper_default();
            let mut r = Pcg64::new(seed);
            for _ in 0..n {
                let x = vec![r.uniform(-4.0, 4.0), r.uniform(-4.0, 4.0)];
                gp.observe(&x, x.iter().sum::<f64>().tanh());
            }
            gp
        };
        let mut rng = Pcg64::new(n as u64 + 9900);
        let batch: Vec<(Vec<f64>, f64)> = (0..1 + n % 5)
            .map(|_| {
                (vec![rng.uniform(-4.0, 4.0), rng.uniform(-4.0, 4.0)], rng.uniform(-1.0, 1.0))
            })
            .collect();
        let probe = vec![rng.uniform(-4.0, 4.0), rng.uniform(-4.0, 4.0)];
        let mut seq = build(n as u64);
        let mut grouped = build(n as u64);
        let before = {
            let (m, v) = seq.predict(&probe);
            (m.to_bits(), v.to_bits())
        };
        for (x, y) in &batch {
            seq.observe_fantasy(x, *y);
        }
        grouped.observe_fantasies(&batch);
        // identical augmented posterior...
        let same_augmented = {
            let (pa, pb) = (seq.posterior(), grouped.posterior());
            bits_eq(pa.alpha, pb.alpha)
                && pa.mean_offset.to_bits() == pb.mean_offset.to_bits()
                && pa.y_scale.to_bits() == pb.y_scale.to_bits()
                && (0..pa.factor.dim()).all(|i| bits_eq(pa.factor.row(i), pb.factor.row(i)))
        };
        // ...and identical bitwise restore on rollback
        let removed_seq = seq.retract_fantasies();
        let removed_grp = grouped.retract_fantasies();
        let after_seq = {
            let (m, v) = seq.predict(&probe);
            (m.to_bits(), v.to_bits())
        };
        let after_grp = {
            let (m, v) = grouped.predict(&probe);
            (m.to_bits(), v.to_bits())
        };
        same_augmented
            && removed_seq == batch.len()
            && removed_grp == batch.len()
            && after_seq == before
            && after_grp == before
            && seq.fantasies_active() == 0
            && grouped.fantasies_active() == 0
    });
}

/// Incremental extension after an arbitrary interleaving of batch and
/// single extensions still reconstructs the full covariance.
#[test]
fn prop_interleaved_extension_reconstructs() {
    let g = pt::usize_in(4, 24);
    pt::check("interleaved_extend", &g, |&n| {
        let mut rng = Pcg64::new(n as u64 + 9300);
        let kernel = Kernel::paper_default();
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..3).map(|_| rng.uniform(-5.0, 5.0)).collect()).collect();
        let k = cov_matrix(&kernel, &xs);
        let mut g2 = GrowingCholesky::new();
        let mut i = 0;
        while i < n {
            // random run length of sequential extends
            let run = 1 + (rng.below(3) as usize).min(n - i - 1).min(n - i);
            for m in i..i + run {
                let p: Vec<f64> = (0..m).map(|j| k[(m, j)]).collect();
                g2.extend(&p, k[(m, m)]);
            }
            i += run;
        }
        let rel = g2.reconstruct().max_abs_diff(&k) / k.fro_norm();
        rel < 1e-10
    });
}
