//! Coordinator integration: the paper's §3.4/§4.4 parallel scheme at test
//! scale — correctness of the synchronized posterior, iteration-efficiency
//! vs sequential, failure resilience, and determinism of suggestions.

use std::sync::Arc;

use lazygp::acquisition::optim::OptimConfig;
use lazygp::bo::driver::{BoConfig, BoDriver, InitDesign, PendingStrategy};
use lazygp::coordinator::{AsyncBo, AsyncCoordinatorConfig, CoordinatorConfig, ParallelBo};
use lazygp::gp::Surrogate;
use lazygp::objectives::trainer::ResNetCifarSim;
use lazygp::objectives::{levy::Levy, suite::Sphere, Objective};

fn fast_bo(seed: u64) -> BoConfig {
    BoConfig::lazy()
        .with_seed(seed)
        .with_init(InitDesign::Lhs(5))
        .with_optim(OptimConfig { candidates: 128, restarts: 4, nm_iters: 25, nm_scale: 0.08 })
}

#[test]
fn parallel_matches_sequential_observation_semantics() {
    // after any round, the surrogate must contain exactly the evaluated
    // points — sync via t incremental extensions must not lose or corrupt
    let obj: Arc<dyn Objective> = Arc::new(Sphere::new(2));
    let mut pbo = ParallelBo::new(
        fast_bo(101),
        obj,
        CoordinatorConfig { workers: 4, batch_size: 5, ..Default::default() },
    );
    pbo.run_rounds(6).unwrap();
    assert_eq!(pbo.driver().history().len(), 5 + 30);
    assert_eq!(pbo.driver().surrogate().len(), 35);
    // posterior must be finite and sane everywhere sampled
    let (m, v) = pbo.driver().surrogate().predict(&[0.1, -0.2]);
    assert!(m.is_finite() && v.is_finite() && v >= 0.0);
}

#[test]
fn parallel_needs_fewer_rounds_than_sequential_iterations() {
    // Table 4's structural claim: hitting a target accuracy takes ~t× fewer
    // *rounds* than sequential iterations (each round trains t models).
    // Start from a single random seed (the paper's setting) so the target
    // is not already hit during initialization.
    let target = 0.80;
    let fast_bo = |seed: u64| {
        BoConfig::lazy()
            .with_seed(seed)
            .with_init(InitDesign::Random(1))
            .with_optim(OptimConfig { candidates: 128, restarts: 4, nm_iters: 25, nm_scale: 0.08 })
    };
    let obj_seq = Box::new(ResNetCifarSim::new());
    let mut seq = BoDriver::new(fast_bo(103), obj_seq);
    let mut seq_iters = None;
    for i in 1..=120 {
        seq.step();
        if seq.best().unwrap().value >= target {
            seq_iters = Some(i);
            break;
        }
    }

    let obj: Arc<dyn Objective> = Arc::new(ResNetCifarSim::new());
    let mut par = ParallelBo::new(
        fast_bo(103),
        obj,
        CoordinatorConfig { workers: 8, batch_size: 8, ..Default::default() },
    );
    let mut par_rounds = None;
    for r in 1..=40 {
        par.round().unwrap();
        if par.driver().best().unwrap().value >= target {
            par_rounds = Some(r);
            break;
        }
    }
    let seq_iters = seq_iters.expect("sequential never reached target");
    let par_rounds = par_rounds.expect("parallel never reached target");
    assert!(
        par_rounds < seq_iters,
        "parallel rounds {par_rounds} should undercut sequential iterations {seq_iters}"
    );
}

#[test]
fn sync_cost_stays_negligible_vs_training() {
    let obj: Arc<dyn Objective> = Arc::new(ResNetCifarSim::new());
    let mut pbo = ParallelBo::new(
        fast_bo(107),
        obj,
        CoordinatorConfig { workers: 8, batch_size: 8, ..Default::default() },
    );
    pbo.run_rounds(5).unwrap();
    for r in pbo.rounds() {
        // simulated training is 190 s; leader sync must be ≪ 1 s
        assert!(
            r.sync_seconds < 0.5,
            "sync {}s is not negligible",
            r.sync_seconds
        );
    }
}

#[test]
fn failure_storm_still_makes_progress() {
    let obj: Arc<dyn Objective> = Arc::new(Levy::new(2));
    let mut pbo = ParallelBo::new(
        fast_bo(109),
        obj,
        CoordinatorConfig {
            workers: 4,
            batch_size: 4,
            fail_prob: 0.4,
            max_retries: 20,
            ..Default::default()
        },
    );
    pbo.run_rounds(5).unwrap();
    let completed: usize = pbo.rounds().iter().map(|r| r.completed).sum();
    assert_eq!(completed, 20, "all trials should complete after retries");
    assert!(pbo.driver().best().unwrap().value.is_finite());
}

#[test]
fn async_coordinator_matches_observation_semantics() {
    // same contract as the sync leader: after a run the surrogate holds
    // exactly the evaluated points, fantasies fully unwound
    let obj: Arc<dyn Objective> = Arc::new(Sphere::new(2));
    let mut abo = AsyncBo::new(
        fast_bo(211),
        obj,
        AsyncCoordinatorConfig { workers: 4, ..Default::default() },
    );
    abo.run_until_evals(30).unwrap();
    assert_eq!(abo.driver().history().len(), 30);
    assert_eq!(abo.driver().surrogate().len(), 30);
    assert_eq!(abo.driver().fantasies_active(), 0);
    let (m, v) = abo.driver().surrogate().predict(&[0.1, -0.2]);
    assert!(m.is_finite() && v.is_finite() && v >= 0.0);
    let s = abo.stats();
    assert_eq!(s.fantasies_issued, s.fantasy_rollbacks);
}

#[test]
fn async_beats_sync_virtual_wall_clock_under_heterogeneous_costs() {
    // The ISSUE-1 acceptance setup: 4 workers, equal evaluation budget,
    // ResNet cost jitter + failure injection (a crashed training retries
    // *sequentially* inside a sync round, while the async leader refills
    // the freed slot immediately). The bench asserts ≥ 1.2×; here we use a
    // slightly looser 1.1× bound to stay robust to OS scheduling noise.
    let evals = 45;
    let workers = 4;
    let fail_prob = 0.25;
    // virtual-slot accounting is scheduling-independent; a small real sleep
    // just keeps completion order resembling virtual order (information
    // realism), it is not needed for the cost bookkeeping
    let sleep_scale = 1e-5;

    let obj: Arc<dyn Objective> = Arc::new(ResNetCifarSim::new());
    let mut sync = ParallelBo::new(
        fast_bo(127),
        obj,
        CoordinatorConfig {
            workers,
            batch_size: workers,
            fail_prob,
            max_retries: 3,
            sleep_scale,
            ..Default::default()
        },
    );
    sync.run_until_evals(evals).unwrap();
    let sync_v = sync.virtual_seconds();

    let obj: Arc<dyn Objective> = Arc::new(ResNetCifarSim::new());
    let mut asy = AsyncBo::new(
        fast_bo(127),
        obj,
        AsyncCoordinatorConfig {
            workers,
            pending: PendingStrategy::ConstantLiarMin,
            fail_prob,
            max_retries: 3,
            sleep_scale,
            ..Default::default()
        },
    );
    asy.run_until_evals(evals).unwrap();
    let async_v = asy.virtual_seconds();

    assert!(sync.driver().history().len() >= evals);
    assert_eq!(asy.driver().history().len(), evals);
    assert!(
        sync_v / async_v > 1.1,
        "async should beat the round barrier: sync {sync_v:.0}s vs async {async_v:.0}s \
         (utilization {:.2})",
        asy.utilization()
    );
    assert!(asy.utilization() > 0.5, "workers should stay busy: {}", asy.utilization());
}

#[test]
fn worker_count_does_not_change_observation_totals() {
    for workers in [1, 2, 8] {
        let obj: Arc<dyn Objective> = Arc::new(Sphere::new(2));
        let mut pbo = ParallelBo::new(
            fast_bo(113),
            obj,
            CoordinatorConfig { workers, batch_size: 4, ..Default::default() },
        );
        pbo.run_rounds(3).unwrap();
        assert_eq!(pbo.driver().history().len(), 5 + 12, "workers={workers}");
    }
}
