//! Crash-replay durability suite: kill a journaled leader at arbitrary
//! points — torn-tail truncation of the on-disk journal, or a live
//! `SocketPool::abort()` mid-study over real loopback workers — restore
//! from disk, and require the resumed run to be **bitwise identical** to
//! one that never crashed: same trial ids, same best-so-far trace bits,
//! same final posterior digest, same RNG position. Plus property tests
//! that recovery is prefix-robust under any truncation/corruption and
//! that snapshot+tail replay equals full-journal replay, and a
//! regression test that fantasy retractions are journaled before
//! `AllWorkersLost` surfaces.
//!
//! `virtual_done_s` embeds real leader seconds and is deliberately never
//! compared here. CI runs this file in its own `durability` job with
//! `--test-threads=1` and a hard timeout; `LAZYGP_DURABILITY_DIR` pins
//! the scratch directory so failed runs can upload their journals.

use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use lazygp::acquisition::optim::OptimConfig;
use lazygp::bo::driver::{Best, BoConfig, InitDesign, PendingStrategy};
use lazygp::config::json::Json;
use lazygp::coordinator::transport::{
    read_frame, read_frame_with, run_worker, run_worker_with, write_frame, write_frame_with,
    FrameConfig, LeaderMsg, ReconnectConfig, Transport, WorkerMsg, WorkerOptions, PROTOCOL_VERSION,
};
use lazygp::coordinator::worker::{WorkerConfig, WorkerPool};
use lazygp::coordinator::{
    journal_path, recover, snapshot_path, AsyncBo, AsyncCoordinatorConfig, OpenInfo,
    RemoteEvalConfig, ReplayEntry, SocketPool, StudyId, StudyJournal, StudyResult, StudyService,
    StudySpec, Trial, TrialError, TrialOutcome, TrialPolicy, JOURNAL_FORMAT,
};
use lazygp::gp::Surrogate;
use lazygp::objectives::{self, Evaluation};
use lazygp::util::proptest as pt;
use lazygp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// harness helpers
// ---------------------------------------------------------------------------

fn fast_bo(seed: u64) -> BoConfig {
    BoConfig::lazy()
        .with_seed(seed)
        .with_init(InitDesign::Lhs(5))
        .with_optim(OptimConfig { candidates: 96, restarts: 3, nm_iters: 20, nm_scale: 0.08 })
}

fn async_cfg(seed: u64) -> AsyncCoordinatorConfig {
    AsyncCoordinatorConfig {
        workers: 1,
        pending: PendingStrategy::ConstantLiarMin,
        sleep_scale: 0.0,
        fail_prob: 0.0,
        max_retries: 2,
        seed,
        ..AsyncCoordinatorConfig::default()
    }
}

/// Scratch root for journals; CI pins it via `LAZYGP_DURABILITY_DIR` so
/// the artifacts of a failed run can be uploaded.
fn scratch_root() -> PathBuf {
    match std::env::var("LAZYGP_DURABILITY_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir().join("lazygp_durability"),
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = scratch_root().join(format!("{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn open_info(name: &str, seed: u64, evals: usize) -> OpenInfo {
    OpenInfo {
        format: JOURNAL_FORMAT,
        study: 0,
        name: name.into(),
        objective: "sphere5".into(),
        seed,
        evals,
        slots: 1,
        pending: "cl-min".into(),
        max_retries: 2,
        surrogate: lazygp::gp::SurrogateSpec::default(),
        policy: TrialPolicy::default(),
    }
}

/// Create-or-resume a solo journal exactly the way a restarted leader
/// would: recover the intact prefix, reattach, keep the replay tail.
fn open_or_resume(
    dir: &Path,
    name: &str,
    seed: u64,
    evals: usize,
    every: u64,
) -> (StudyJournal, Vec<ReplayEntry>) {
    match recover(dir, name).expect("recover never fails on a repairable journal") {
        Some(rec) => {
            let entries = rec.entries.clone();
            let j = StudyJournal::resume(dir, &rec).expect("reattach").with_snapshot_every(every);
            (j, entries)
        }
        None => {
            let j = StudyJournal::create(dir, open_info(name, seed, evals))
                .expect("create journal")
                .with_snapshot_every(every);
            (j, Vec::new())
        }
    }
}

/// Everything a run must reproduce bitwise after a crash (deliberately
/// excludes `virtual_done_s`, which embeds real leader seconds).
#[derive(Debug, Clone, PartialEq, Eq)]
struct RunFacts {
    trial_ids: Vec<u64>,
    best_trace_bits: Vec<u64>,
    best_value_bits: u64,
    best_x_bits: Vec<u64>,
    posterior_digest: u64,
    rng_draws: u64,
}

fn facts(abo: &AsyncBo, best: &Best) -> RunFacts {
    RunFacts {
        trial_ids: abo.events().iter().map(|e| e.trial_id).collect(),
        best_trace_bits: abo.events().iter().map(|e| e.best.to_bits()).collect(),
        best_value_bits: best.value.to_bits(),
        best_x_bits: best.x.iter().map(|v| v.to_bits()).collect(),
        posterior_digest: abo.driver().surrogate().state_digest(),
        rng_draws: abo.driver().rng().draws(),
    }
}

/// Thread-fleet solo leader, journaled iff `journal_dir` is given;
/// resumes an existing journal in the directory automatically.
fn solo_run(journal_dir: Option<&Path>, seed: u64, evals: usize, every: u64) -> RunFacts {
    let obj: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name("sphere5").unwrap());
    let pool = WorkerPool::spawn(
        Arc::clone(&obj),
        WorkerConfig { workers: 1, seed: seed ^ 0x9e37_79b9_7f4a_7c15, ..WorkerConfig::default() },
    );
    let mut abo = AsyncBo::with_transport(fast_bo(seed), obj, Box::new(pool), async_cfg(seed));
    if let Some(dir) = journal_dir {
        let (journal, replay) = open_or_resume(dir, "solo", seed, evals, every);
        abo = abo.with_journal(journal, replay);
    }
    let best = abo.run_until_evals(evals).expect("run completes");
    let f = facts(&abo, &best);
    abo.finish();
    f
}

/// Plant a (possibly truncated) journal copy and the golden snapshot in
/// a fresh directory, as left behind by a crash.
fn plant(dir: &Path, name: &str, journal: &[u8], snapshot: Option<&[u8]>) {
    std::fs::write(journal_path(dir, name), journal).expect("plant journal");
    if let Some(s) = snapshot {
        std::fs::write(snapshot_path(dir, name), s).expect("plant snapshot");
    }
}

/// Offsets of every complete-frame boundary in `bytes` (0 included).
fn frame_boundaries(bytes: &[u8]) -> Vec<usize> {
    let cfg = FrameConfig { checksum: true, ..FrameConfig::default() };
    let mut offsets = vec![0usize];
    let mut slice: &[u8] = bytes;
    while !slice.is_empty() {
        if read_frame_with(&mut slice, &cfg).is_err() {
            break;
        }
        offsets.push(bytes.len() - slice.len());
    }
    offsets
}

// ---------------------------------------------------------------------------
// tentpole: crash + restore is bitwise-identical (solo, thread fleet)
// ---------------------------------------------------------------------------

/// Truncate the golden journal at every record boundary and at random
/// mid-record byte offsets — each prefix is exactly what some crash
/// instant leaves on disk — then resume and demand bitwise equality
/// with the uninterrupted run. Also checks that journaling itself does
/// not perturb the run (journaled golden == unjournaled run).
#[test]
fn solo_resume_is_bitwise_identical_after_any_truncation() {
    const SEED: u64 = 41;
    const EVALS: usize = 11;
    let golden_dir = fresh_dir("solo_golden");
    let golden = solo_run(Some(&golden_dir), SEED, EVALS, 3);

    let plain = solo_run(None, SEED, EVALS, 3);
    assert_eq!(golden, plain, "journaling must not perturb the decision stream");

    let journal = std::fs::read(journal_path(&golden_dir, "solo")).expect("golden journal");
    let snapshot = std::fs::read(snapshot_path(&golden_dir, "solo")).ok();

    let mut cuts = frame_boundaries(&journal);
    let mut rng = Pcg64::new(0xD00D);
    for _ in 0..5 {
        cuts.push((rng.next_u64() % journal.len() as u64) as usize); // mid-record tears
    }
    for (i, &cut) in cuts.iter().enumerate() {
        let dir = fresh_dir(&format!("solo_cut_{i}"));
        plant(&dir, "solo", &journal[..cut], snapshot.as_deref());
        let resumed = solo_run(Some(&dir), SEED, EVALS, 3);
        assert_eq!(resumed, golden, "resume after a crash at journal byte {cut} diverged");
    }
}

// ---------------------------------------------------------------------------
// tentpole: SocketPool::abort() kill + restore over real TCP workers
// ---------------------------------------------------------------------------

/// Loopback fleet of one real worker daemon with fast, finite reconnect
/// (so workers orphaned by an abort exit instead of spinning).
fn tcp_fleet(seed: u64) -> (SocketPool, std::thread::JoinHandle<()>) {
    let pool = SocketPool::listen(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed,
            policy: TrialPolicy::default(),
        },
    )
    .expect("bind loopback");
    // flip ACK mode before the worker is admitted, so its Welcome already
    // advertises it and the daemon retains outcomes until ACKed
    pool.preload_gate(&[]);
    let addr = pool.local_addr().to_string();
    let worker = std::thread::spawn(move || {
        let opts = WorkerOptions {
            threads: 1,
            reconnect: ReconnectConfig {
                max_attempts: 4,
                base_backoff: Duration::from_millis(25),
                max_backoff: Duration::from_millis(200),
                jitter_seed: 7,
            },
            ..Default::default()
        };
        let _ = run_worker_with(&addr, opts); // Err is fine after an abort
    });
    pool.wait_for_capacity(1, Duration::from_secs(10)).expect("worker connects");
    (pool, worker)
}

/// One journaled leader over a fresh TCP fleet: run to `stop` evals,
/// then either crash (`abort`) or finish cleanly and report facts.
fn tcp_run(dir: &Path, seed: u64, evals: usize, stop: usize, crash: bool) -> Option<RunFacts> {
    let (pool, worker) = tcp_fleet(seed);
    let obj: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name("sphere5").unwrap());
    let (journal, replay) = open_or_resume(dir, "tcp", seed, evals, 3);
    let mut abo = AsyncBo::with_transport(fast_bo(seed), obj, Box::new(pool), async_cfg(seed))
        .with_journal(journal, replay);
    let best = abo.run_until_evals(stop).expect("run reaches the stop point");
    let f = facts(&abo, &best);
    if crash {
        abo.abort(); // no teardown courtesy: links die, journal handle drops
    } else {
        abo.finish();
    }
    worker.join().unwrap();
    if crash {
        None
    } else {
        Some(f)
    }
}

/// Kill the leader with `SocketPool::abort()` at randomized eval counts
/// mid-study — links die abruptly, the journal handle drops with no
/// teardown courtesy — then restore onto a brand-new fleet and demand
/// the completed run match the never-crashed golden bitwise.
#[test]
fn tcp_abort_kill_then_resume_matches_uninterrupted_run() {
    const SEED: u64 = 43;
    const EVALS: usize = 11;
    let golden_dir = fresh_dir("abort_golden");
    let golden = tcp_run(&golden_dir, SEED, EVALS, EVALS, false).unwrap();

    let mut rng = Pcg64::new(0xFEED);
    for i in 0..3 {
        let stop = 6 + (rng.next_u64() % (EVALS as u64 - 6)) as usize;
        let dir = fresh_dir(&format!("abort_{i}"));
        assert!(tcp_run(&dir, SEED, EVALS, stop, true).is_none());
        let rec = recover(&dir, "tcp").unwrap().expect("crash left a journal");
        assert!(!rec.finished, "a killed study must not carry a finish record");
        assert_eq!(rec.entries.len(), stop, "every settled outcome survived the abort");
        let resumed = tcp_run(&dir, SEED, EVALS, EVALS, false).unwrap();
        assert_eq!(resumed, golden, "resume after abort at {stop} evals diverged");
    }
}

// ---------------------------------------------------------------------------
// tentpole: two concurrent studies on one fleet, crash + restore
// ---------------------------------------------------------------------------

fn service_pair(dir: &Path, evals: usize) -> (StudyResult, StudyResult) {
    let base: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name("sphere5").unwrap());
    let fleet = WorkerPool::spawn(base, WorkerConfig { workers: 2, ..WorkerConfig::default() });
    let service = StudyService::new(Box::new(fleet)).with_journal_dir(dir);
    let a = service
        .create_study(StudySpec::new("svc-a", "sphere5").with_bo(fast_bo(11)).with_evals(evals))
        .unwrap();
    let b = service
        .create_study(StudySpec::new("svc-b", "levy2").with_bo(fast_bo(23)).with_evals(evals))
        .unwrap();
    let ra = service.wait(a).expect("study a completes");
    let rb = service.wait(b).expect("study b completes");
    service.shutdown().unwrap();
    (ra, rb)
}

fn assert_study_match(resumed: &StudyResult, golden: &StudyResult, tag: &str) {
    let rb = resumed.best.as_ref().expect("resumed study found a best");
    let gb = golden.best.as_ref().expect("golden study found a best");
    assert_eq!(rb.value.to_bits(), gb.value.to_bits(), "{tag}: best value drifted");
    assert_eq!(rb.x.len(), gb.x.len());
    for (r, g) in rb.x.iter().zip(&gb.x) {
        assert_eq!(r.to_bits(), g.to_bits(), "{tag}: best x drifted");
    }
    assert_eq!(resumed.trace.points.len(), golden.trace.points.len(), "{tag}: event count");
    for (rp, gp) in resumed.trace.points.iter().zip(&golden.trace.points) {
        assert_eq!(rp.trial_id, gp.trial_id, "{tag}: trial order drifted");
        assert_eq!(rp.best.to_bits(), gp.best.to_bits(), "{tag}: best-so-far trace drifted");
        // virtual_done_s is NOT compared: it embeds real leader seconds
    }
}

/// Two concurrent studies share one fleet and one journal directory;
/// both journals are truncated at independent random crash points, and
/// a fresh `StudyService` must restore both to bitwise equality with
/// the uninterrupted golden pair.
#[test]
fn two_study_service_resumes_bitwise_after_truncation() {
    const EVALS: usize = 10;
    let golden_dir = fresh_dir("svc_golden");
    let (ga, gb) = service_pair(&golden_dir, EVALS);

    let ja = std::fs::read(journal_path(&golden_dir, "svc-a")).expect("journal a");
    let jb = std::fs::read(journal_path(&golden_dir, "svc-b")).expect("journal b");
    let sa = std::fs::read(snapshot_path(&golden_dir, "svc-a")).ok();
    let sb = std::fs::read(snapshot_path(&golden_dir, "svc-b")).ok();

    let mut rng = Pcg64::new(0xBEEF);
    for i in 0..3 {
        let ca = (rng.next_u64() % (ja.len() as u64 + 1)) as usize;
        let cb = (rng.next_u64() % (jb.len() as u64 + 1)) as usize;
        let dir = fresh_dir(&format!("svc_cut_{i}"));
        plant(&dir, "svc-a", &ja[..ca], sa.as_deref());
        plant(&dir, "svc-b", &jb[..cb], sb.as_deref());
        let (ra, rb) = service_pair(&dir, EVALS);
        assert_study_match(&ra, &ga, &format!("study a, crash at byte {ca}"));
        assert_study_match(&rb, &gb, &format!("study b, crash at byte {cb}"));
    }
}

// ---------------------------------------------------------------------------
// property: recovery is prefix-robust under truncation and corruption
// ---------------------------------------------------------------------------

fn fake_outcome(id: u64, value: f64, ok: bool) -> TrialOutcome {
    TrialOutcome {
        trial: Trial { id, study: StudyId::SOLO, round: id, x: vec![value, -value], attempt: 0 },
        worker_id: 0,
        result: if ok {
            Ok(Evaluation { value, sim_cost_s: 0.5 })
        } else {
            Err(TrialError::SimulatedCrash)
        },
        worker_seconds: 0.0,
        sim_cost_s: 0.5,
    }
}

/// The bits of a replay entry that matter for exactly-once replay.
fn entry_sig(e: &ReplayEntry) -> (u64, u64, bool, u64) {
    let vbits = match &e.outcome.result {
        Ok(ev) => ev.value.to_bits(),
        Err(_) => u64::MAX,
    };
    (e.outcome.trial.id, e.rng_draws, e.outcome.is_ok(), vbits)
}

/// Write a synthetic 10-outcome journal (with dispatches, two snapshot
/// rotations, a retract and a finish) and return its bytes + snapshot.
fn synthetic_journal(dir: &Path, name: &str) -> (Vec<u8>, Vec<u8>) {
    let mut j = StudyJournal::create(dir, open_info(name, 17, 10))
        .expect("create")
        .with_snapshot_every(4);
    for id in 0..10u64 {
        let t =
            Trial { id, study: StudyId::SOLO, round: id, x: vec![0.25 * id as f64], attempt: 0 };
        j.append_dispatch(&t).unwrap();
        j.append_outcome(&fake_outcome(id, 0.125 * id as f64 - 3.0, id % 7 != 3), 100 + id)
            .unwrap();
        if j.snapshot_due() {
            j.write_snapshot(true).unwrap();
        }
    }
    j.append_retract(1).unwrap();
    j.append_finish().unwrap();
    drop(j);
    let jb = std::fs::read(journal_path(dir, name)).unwrap();
    let sb = std::fs::read(snapshot_path(dir, name)).unwrap();
    (jb, sb)
}

/// Any truncation — at a record boundary or mid-record — and any
/// single-byte corruption of the journal must recover to a consistent
/// prefix of the golden entries or a typed journal error: never a
/// panic, never a duplicated `(study, trial)` through the gate, and a
/// second recovery after the self-repair must be clean.
#[test]
fn property_recovery_survives_truncation_and_corruption() {
    let golden_dir = fresh_dir("prop_golden");
    let (journal, snapshot) = synthetic_journal(&golden_dir, "prop");
    let full = recover(&golden_dir, "prop").unwrap().expect("golden recovers");
    assert!(full.finished && full.entries.len() == 10 && full.retracted == 1);

    let len = journal.len() as u64;
    let gen = pt::Gen::no_shrink(move |rng: &mut Pcg64| {
        let cut = (rng.next_u64() % (len + 1)) as usize;
        let flip = rng.next_u64() % 4 == 0 && cut > 0;
        let pos = if cut > 0 { (rng.next_u64() % cut as u64) as usize } else { 0 };
        (cut, flip, pos)
    });
    pt::check("journal recovery is prefix-consistent", &gen, |&(cut, flip, pos)| {
        let dir = fresh_dir("prop_case");
        let mut bytes = journal[..cut].to_vec();
        if flip {
            bytes[pos] ^= 0x40;
        }
        plant(&dir, "prop", &bytes, Some(snapshot.as_slice()));
        match recover(&dir, "prop") {
            Err(e) => e.is_journal(), // typed, never a panic
            Ok(None) => true,         // nothing intact: a fresh start
            Ok(Some(rec)) => {
                let prefix = rec.entries.len() <= full.entries.len()
                    && rec
                        .entries
                        .iter()
                        .zip(&full.entries)
                        .all(|(a, b)| entry_sig(a) == entry_sig(b));
                let mut keys = rec.gate_keys();
                let n = keys.len();
                keys.sort_unstable();
                keys.dedup();
                // self-repair truncated the torn tail: re-recovery is clean
                let again = recover(&dir, "prop");
                prefix && keys.len() == n && again.is_ok()
            }
        }
    });
}

/// CRC-valid frames with garbage schemas are *not* torn tails: they must
/// surface as typed `Error::Journal`, not be skipped or panic.
#[test]
fn schema_violations_are_typed_errors() {
    let cfg = FrameConfig { checksum: true, ..FrameConfig::default() };

    // a well-framed record of an unknown type appended to a valid journal
    let dir = fresh_dir("bad_schema");
    let (journal, snapshot) = synthetic_journal(&dir, "bad");
    let mut bytes = journal.clone();
    write_frame_with(&mut bytes, &Json::obj(vec![("type", Json::Str("mystery".into()))]), &cfg)
        .unwrap();
    plant(&dir, "bad", &bytes, Some(snapshot.as_slice()));
    let err = recover(&dir, "bad").expect_err("unknown record type");
    assert!(err.is_journal(), "got {err}");

    // a journal whose first record is not `open`
    let dir = fresh_dir("no_open");
    let mut bytes = Vec::new();
    write_frame_with(&mut bytes, &Json::obj(vec![("type", Json::Str("finish".into()))]), &cfg)
        .unwrap();
    plant(&dir, "headless", &bytes, None);
    let err = recover(&dir, "headless").expect_err("journal without open");
    assert!(err.is_journal(), "got {err}");
}

// ---------------------------------------------------------------------------
// property: snapshot + journal-tail replay == full-journal replay
// ---------------------------------------------------------------------------

/// Two concurrent studies' outcome streams, randomly interleaved onto
/// one directory, each journaled twice: once plain (never snapshots)
/// and once with aggressive snapshot rotation. Recovery from the
/// rotated journal (snapshot + tail) must be bitwise identical to
/// recovery from the full journal, for every interleaving.
#[test]
fn property_snapshot_plus_tail_equals_full_journal() {
    let gen = pt::Gen::no_shrink(|rng: &mut Pcg64| {
        let na = 4 + (rng.next_u64() % 8) as usize;
        let nb = 4 + (rng.next_u64() % 8) as usize;
        let every = 1 + rng.next_u64() % 4;
        let mut order = Vec::new();
        let (mut i, mut k) = (0usize, 0usize);
        while i < na || k < nb {
            let pick_a = k >= nb || (i < na && rng.next_u64() % 2 == 0);
            order.push(pick_a);
            if pick_a {
                i += 1;
            } else {
                k += 1;
            }
        }
        let values: Vec<f64> =
            (0..order.len()).map(|_| (rng.next_u64() % 2000) as f64 * 0.125 - 125.0).collect();
        (order, values, every)
    });
    pt::check("snapshot+tail equals full journal", &gen, |(order, values, every)| {
        let plain = fresh_dir("snap_plain");
        let rotated = fresh_dir("snap_rot");
        for (dir, cadence) in [(&plain, 0u64), (&rotated, *every)] {
            let mut ja = StudyJournal::create(dir, open_info("ia", 5, 64))
                .unwrap()
                .with_snapshot_every(cadence);
            let mut jb = StudyJournal::create(dir, open_info("ib", 9, 64))
                .unwrap()
                .with_snapshot_every(cadence);
            let (mut ida, mut idb) = (0u64, 0u64);
            for (ev, &a_next) in order.iter().enumerate() {
                let (j, id) = if a_next {
                    ida += 1;
                    (&mut ja, ida)
                } else {
                    idb += 1;
                    (&mut jb, idb)
                };
                let o = fake_outcome(id, values[ev], ev % 5 != 4);
                j.append_dispatch(&o.trial).unwrap();
                j.append_outcome(&o, ev as u64).unwrap();
                if j.snapshot_due() {
                    j.write_snapshot(true).unwrap();
                }
            }
        }
        ["ia", "ib"].iter().all(|name| {
            let f = recover(&plain, name).unwrap().expect("plain journal recovers");
            let r = recover(&rotated, name).unwrap().expect("rotated journal recovers");
            // rotation really happened (cadence <= stream length here)
            snapshot_path(&rotated, name).exists()
                && f.entries.len() == r.entries.len()
                && f.entries.iter().zip(&r.entries).all(|(x, y)| entry_sig(x) == entry_sig(y))
        })
    });
}

// ---------------------------------------------------------------------------
// regression: retractions are journaled before AllWorkersLost surfaces
// ---------------------------------------------------------------------------

fn read_dispatch_skipping_acks(stream: &mut TcpStream, timeout: Duration) -> Option<Trial> {
    stream.set_read_timeout(Some(timeout)).unwrap();
    loop {
        match read_frame(stream) {
            Ok((json, _)) => match LeaderMsg::from_json(&json).ok()? {
                LeaderMsg::Dispatch(t) => return Some(t),
                _ => continue, // Acks and pings are not this script's business
            },
            Err(_) => return None,
        }
    }
}

/// A scripted worker serves three outcomes and vanishes with the fourth
/// trial in flight. The leader must journal the fantasy retraction
/// *before* surfacing `AllWorkersLost`, so the on-disk study is an
/// honest crash shape: three settled outcomes, a retract, no finish.
#[test]
fn retract_is_journaled_before_all_workers_lost_surfaces() {
    use lazygp::coordinator::SocketPoolOptions;
    let dir = fresh_dir("lost");
    let pool = SocketPool::listen_with(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed: 3,
            policy: TrialPolicy::default(),
        },
        SocketPoolOptions {
            heartbeat_interval: Duration::ZERO,
            worker_loss_deadline: Duration::from_millis(300),
            ..Default::default()
        },
    )
    .expect("bind loopback");
    // flip ACK mode before the scripted worker connects: its Welcome must
    // already advertise it (with_journal re-preloads the gate, a no-op)
    pool.preload_gate(&[]);
    let addr = pool.local_addr();

    let script = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect");
        let hello = WorkerMsg::Hello { protocol: PROTOCOL_VERSION, capacity: 1, resume: None };
        write_frame(&mut stream, &hello.to_json()).expect("send hello");
        let (welcome, _) = read_frame(&mut stream).expect("read welcome");
        let LeaderMsg::Welcome { acks, .. } = LeaderMsg::from_json(&welcome).unwrap() else {
            panic!("expected welcome");
        };
        assert!(acks, "a journaled leader must advertise ACK mode in its Welcome");
        for _ in 0..3 {
            let t = read_dispatch_skipping_acks(&mut stream, Duration::from_secs(5))
                .expect("dispatch arrives");
            let outcome = TrialOutcome {
                worker_id: 0,
                result: Ok(Evaluation { value: -1.0 - t.id as f64, sim_cost_s: 1.0 }),
                worker_seconds: 0.0,
                sim_cost_s: 1.0,
                trial: t,
            };
            write_frame(&mut stream, &WorkerMsg::Outcome(outcome).to_json()).expect("send");
        }
        // vanish with the fourth trial in flight
    });

    pool.wait_for_capacity(1, Duration::from_secs(10)).expect("script connects");
    let obj: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name("sphere5").unwrap());
    let (journal, replay) = open_or_resume(&dir, "lost", 3, 8, 0);
    assert!(replay.is_empty());
    let mut abo = AsyncBo::with_transport(fast_bo(3), obj, Box::new(pool), async_cfg(3))
        .with_journal(journal, replay);
    let err = abo.run_until_evals(8).expect_err("fleet dies mid-study");
    assert!(err.is_all_workers_lost(), "got {err}");

    let trace = abo.trace("lost");
    assert!(trace.journal.records_appended > 0 && trace.journal.fsyncs > 0);
    abo.abort();
    script.join().unwrap();

    let rec = recover(&dir, "lost").unwrap().expect("journal survives");
    assert_eq!(rec.entries.len(), 3, "every settled outcome was journaled");
    assert_eq!(rec.retracted, 1, "the in-flight fantasy's retraction is on disk");
    assert!(!rec.finished, "a dead study must not read as finished");
}

// ---------------------------------------------------------------------------
// smoke: worker-side redelivery buffer drains on ACK
// ---------------------------------------------------------------------------

/// End-to-end ACK handshake over real daemons: a journaled TCP run
/// completes exactly-once (the leader's per-outcome ACKs drain the
/// daemon's retention buffer en route), and a plain non-journaled
/// leader still interoperates with the same daemon code untouched.
#[test]
fn acked_workers_complete_without_redelivery() {
    const SEED: u64 = 47;
    const EVALS: usize = 8;
    let dir = fresh_dir("ack_smoke");
    let f = tcp_run(&dir, SEED, EVALS, EVALS, false).unwrap();
    assert_eq!(f.trial_ids.len(), EVALS);
    let rec = recover(&dir, "tcp").unwrap().expect("journal");
    assert!(rec.finished && rec.entries.len() == EVALS);

    // a plain (non-journaled) leader still speaks to the same daemons
    let (pool, worker) = {
        let pool = SocketPool::listen(
            "127.0.0.1:0",
            RemoteEvalConfig {
                objective: "sphere5".into(),
                sleep_scale: 0.0,
                fail_prob: 0.0,
                seed: SEED,
                policy: TrialPolicy::default(),
            },
        )
        .expect("bind loopback");
        let addr = pool.local_addr().to_string();
        let worker = std::thread::spawn(move || {
            run_worker(&addr, 1).expect("worker run");
        });
        pool.wait_for_capacity(1, Duration::from_secs(10)).expect("worker connects");
        (pool, worker)
    };
    let obj: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name("sphere5").unwrap());
    let mut abo = AsyncBo::with_transport(fast_bo(SEED), obj, Box::new(pool), async_cfg(SEED));
    let best = abo.run_until_evals(EVALS).expect("plain run completes");
    assert!(best.value.is_finite());
    abo.finish();
    worker.join().unwrap();
}
