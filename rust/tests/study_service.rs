//! Multi-study integration over loopback TCP: two concurrent studies with
//! different objectives and seeds share ONE `SocketPool` fleet (real
//! `lazygp worker` daemons), and each study's run must be bitwise
//! identical to the same study run solo on a one-worker fleet with the
//! same seed. Also exercises the per-study transport counters and the
//! lifecycle control plane end-to-end.
//!
//! CI runs this file in its own `study-service` job with
//! `--test-threads=1` and a hard timeout, like `net_faults`.

use std::sync::Arc;
use std::time::Duration;

use lazygp::acquisition::optim::OptimConfig;
use lazygp::bo::driver::{BoConfig, InitDesign, PendingStrategy};
use lazygp::coordinator::transport::run_worker;
use lazygp::coordinator::{
    AsyncBo, AsyncCoordinatorConfig, ControlClient, CreateStudy, RemoteEvalConfig, SocketPool,
    StudyResult, StudyService, StudySpec, TrialPolicy,
};
use lazygp::metrics::AsyncTrace;
use lazygp::objectives;

fn fast_bo(seed: u64) -> BoConfig {
    BoConfig::lazy()
        .with_seed(seed)
        .with_init(InitDesign::Lhs(5))
        .with_optim(OptimConfig { candidates: 96, restarts: 3, nm_iters: 20, nm_scale: 0.08 })
}

/// Bind a loopback fleet and spawn `n` real worker daemons against it.
fn tcp_fleet(n: usize, seed: u64) -> (SocketPool, Vec<std::thread::JoinHandle<()>>) {
    let pool = SocketPool::listen(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed,
            policy: TrialPolicy::default(),
        },
    )
    .expect("bind loopback");
    let addr = pool.local_addr().to_string();
    let workers = (0..n)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run_worker(&addr, 1).expect("worker run");
            })
        })
        .collect();
    pool.wait_for_capacity(n, Duration::from_secs(10)).expect("workers connect");
    (pool, workers)
}

/// Run one study alone on a fresh one-worker TCP fleet — the reference
/// the shared-fleet run must match bitwise.
fn solo_run(objective: &str, seed: u64, evals: usize) -> (lazygp::bo::driver::Best, AsyncTrace) {
    let (pool, workers) = tcp_fleet(1, seed);
    let obj: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name(objective).unwrap());
    let mut abo = AsyncBo::with_transport(
        fast_bo(seed),
        obj,
        Box::new(pool),
        AsyncCoordinatorConfig {
            workers: 1,
            pending: PendingStrategy::ConstantLiarMin,
            sleep_scale: 0.0,
            fail_prob: 0.0,
            max_retries: 2,
            seed,
            ..AsyncCoordinatorConfig::default()
        },
    );
    let best = abo.run_until_evals(evals).expect("solo run completes");
    let trace = abo.trace(objective);
    abo.finish();
    for w in workers {
        w.join().unwrap();
    }
    (best, trace)
}

fn assert_bitwise_match(
    shared: &StudyResult,
    solo_best: &lazygp::bo::driver::Best,
    solo: &AsyncTrace,
) {
    let shared_best = shared.best.as_ref().expect("shared run found a best");
    assert_eq!(shared_best.value.to_bits(), solo_best.value.to_bits(), "best value drifted");
    assert_eq!(shared_best.x.len(), solo_best.x.len());
    for (s, o) in shared_best.x.iter().zip(&solo_best.x) {
        assert_eq!(s.to_bits(), o.to_bits(), "best x drifted");
    }
    assert_eq!(shared.trace.points.len(), solo.points.len(), "event count drifted");
    for (sp, op) in shared.trace.points.iter().zip(&solo.points) {
        assert_eq!(sp.trial_id, op.trial_id, "trial order drifted");
        assert_eq!(sp.best.to_bits(), op.best.to_bits(), "best-so-far trace drifted");
        assert_eq!(sp.virtual_done_s.to_bits(), op.virtual_done_s.to_bits());
    }
}

#[test]
fn two_studies_over_one_tcp_fleet_match_solo_runs_bitwise() {
    const EVALS: usize = 10;
    let (pool, workers) = tcp_fleet(2, 3);
    let service = StudyService::new(Box::new(pool));
    let a = service
        .create_study(StudySpec::new("tcp-a", "sphere5").with_bo(fast_bo(11)).with_evals(EVALS))
        .unwrap();
    let b = service
        .create_study(StudySpec::new("tcp-b", "levy2").with_bo(fast_bo(23)).with_evals(EVALS))
        .unwrap();
    let shared_a = service.wait(a).unwrap();
    let shared_b = service.wait(b).unwrap();

    // per-study transport accounting reconciles exactly: no failures, no
    // disconnects ⇒ dispatched == completed == the study's eval budget
    let stats = service.stats();
    assert_eq!(stats.backend, "tcp");
    assert_eq!(stats.studies.len(), 2, "one counter row per registered study");
    for id in [a, b] {
        let row = stats.studies.iter().find(|r| r.study == id.0).expect("study row");
        assert_eq!(row.dispatched, EVALS as u64, "study {id} dispatched");
        assert_eq!(row.completed, EVALS as u64, "study {id} completed");
        assert_eq!(row.requeued, 0);
        assert_eq!(row.duplicates_dropped, 0);
        // finished studies release the O(n²) factor; observation vectors
        // (5 LHS seeds + EVALS points, 16 bytes each) remain
        assert_eq!(row.mem_bytes_est, 16 * (5 + EVALS as u64));
    }
    service.shutdown().unwrap();
    for w in workers {
        w.join().unwrap();
    }

    let (solo_best_a, solo_trace_a) = solo_run("sphere5", 11, EVALS);
    assert_bitwise_match(&shared_a, &solo_best_a, &solo_trace_a);
    let (solo_best_b, solo_trace_b) = solo_run("levy2", 23, EVALS);
    assert_bitwise_match(&shared_b, &solo_best_b, &solo_trace_b);
}

#[test]
fn control_plane_drives_studies_over_tcp() {
    let (pool, workers) = tcp_fleet(2, 7);
    let service = Arc::new(StudyService::new(Box::new(pool)));
    let server = Arc::clone(&service).serve_control("127.0.0.1:0").unwrap();
    let mut client = ControlClient::connect(server.addr()).unwrap();

    let mut params = CreateStudy::new("ctl-a", "sphere5");
    params.seed = 5;
    params.evals = 6;
    let a = client.create(&params).unwrap();

    // a second study, suspended right after creation: admission must stop
    let mut params_b = CreateStudy::new("ctl-b", "levy2");
    params_b.seed = 9;
    params_b.evals = 8;
    let b = client.create(&params_b).unwrap();
    client.suspend(b).unwrap();
    std::thread::sleep(Duration::from_millis(80));
    let (state_b, _, completed_b, _) = client.query_best(b).unwrap();
    assert_eq!(state_b, "suspended");
    assert!(completed_b < 8, "suspended study kept completing ({completed_b})");

    let result_a = service.wait(a).unwrap();
    assert!(result_a.best.is_some());
    let (state_a, best_a, completed_a, dispatched_a) = client.query_best(a).unwrap();
    assert_eq!(state_a, "finished");
    assert!(best_a.is_finite());
    assert_eq!(completed_a, 6);
    assert_eq!(dispatched_a, 6);

    let rows = client.stream_trace(a).unwrap();
    assert_eq!(rows.len(), 6);
    assert!(rows.iter().all(|r| r.ok && r.value.is_finite()));
    // best-so-far is monotone non-decreasing along the settle order
    for pair in rows.windows(2) {
        assert!(pair[1].best >= pair[0].best);
    }

    client.resume(b).unwrap();
    let result_b = service.wait(b).unwrap();
    assert!(result_b.best.is_some());
    let (state_b, _, completed_b, _) = client.query_best(b).unwrap();
    assert_eq!(state_b, "finished");
    assert_eq!(completed_b, 8);

    let render = client.stats_render().unwrap();
    assert!(render.contains("study"), "render lists study rows:\n{render}");
    assert!(client.create(&CreateStudy::new("bad", "no-such-objective")).is_err());
    client.bye().unwrap();
    drop(server);
    let service = Arc::try_unwrap(service).ok().expect("server released its handle");
    service.shutdown().unwrap();
    for w in workers {
        w.join().unwrap();
    }
}
