//! Wire-protocol and TCP-transport integration tests: bitwise JSON
//! round-trips for the coordinator messages (property-tested), frame
//! robustness, and the loopback leader/worker flows — including the
//! requeue-on-disconnect fault path and prompt teardown.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lazygp::bo::driver::{BoConfig, InitDesign, PendingStrategy};
use lazygp::config::json::Json;
use lazygp::coordinator::transport::{
    read_frame, run_worker, write_frame, LeaderMsg, Transport, WorkerMsg, PROTOCOL_VERSION,
};
use lazygp::coordinator::{
    AsyncBo, AsyncCoordinatorConfig, RemoteEvalConfig, SocketPool, StudyId, Trial, TrialError,
    TrialOutcome, TrialPolicy,
};
use lazygp::gp::Surrogate;
use lazygp::objectives::Evaluation;
use lazygp::util::proptest as pt;
use lazygp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// property tests: the wire encoding round-trips bitwise
// ---------------------------------------------------------------------------

/// Floats that historically break naive encoders: negative zero,
/// subnormals, extreme magnitudes, non-terminating binary fractions.
fn tricky_f64(rng: &mut Pcg64) -> f64 {
    match rng.below(8) {
        0 => -0.0,
        1 => 5e-324,              // smallest subnormal
        2 => f64::MIN_POSITIVE,   // smallest normal
        3 => f64::MAX,
        4 => -f64::MAX,
        5 => 1.0 / 3.0,
        6 => rng.uniform(-1e15, 1e15),
        _ => rng.uniform(-10.0, 10.0),
    }
}

fn random_trial(rng: &mut Pcg64) -> Trial {
    let dim = 1 + rng.below(6) as usize;
    Trial {
        // ids anywhere in the safe-integer range the decoder accepts
        id: rng.below(9_007_199_254_740_992),
        study: StudyId(rng.below(1 << 20)),
        round: rng.below(1 << 30),
        x: (0..dim).map(|_| tricky_f64(rng)).collect(),
        attempt: rng.below(u64::from(u32::MAX) + 1) as u32,
    }
}

fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_trial_json_roundtrip_bitwise() {
    let seeds = pt::usize_in(0, 1_000_000);
    pt::check("trial_wire_roundtrip", &seeds, |&seed| {
        let mut rng = Pcg64::new(seed as u64);
        let t = random_trial(&mut rng);
        let back = Trial::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        back.id == t.id
            && back.study == t.study
            && back.round == t.round
            && back.attempt == t.attempt
            && bits_equal(&t.x, &back.x)
    });
}

#[test]
fn prop_outcome_json_roundtrip_bitwise() {
    let seeds = pt::usize_in(0, 1_000_000);
    pt::check("outcome_wire_roundtrip", &seeds, |&seed| {
        let mut rng = Pcg64::new(seed as u64);
        let trial = random_trial(&mut rng);
        let result = match rng.below(3) {
            0 => Ok(Evaluation { value: tricky_f64(&mut rng), sim_cost_s: rng.uniform(0.0, 500.0) }),
            1 => Err(TrialError::SimulatedCrash),
            _ => Err(TrialError::NonFinite(if rng.below(2) == 0 {
                f64::INFINITY
            } else {
                f64::NEG_INFINITY
            })),
        };
        let o = TrialOutcome {
            trial,
            worker_id: rng.below(64) as usize,
            result,
            worker_seconds: rng.uniform(0.0, 1.0),
            sim_cost_s: tricky_f64(&mut rng).abs(),
        };
        let back =
            TrialOutcome::from_json(&Json::parse(&o.to_json().to_string()).unwrap()).unwrap();
        let result_matches = match (&o.result, &back.result) {
            (Ok(a), Ok(b)) => {
                a.value.to_bits() == b.value.to_bits()
                    && a.sim_cost_s.to_bits() == b.sim_cost_s.to_bits()
            }
            (Err(TrialError::SimulatedCrash), Err(TrialError::SimulatedCrash)) => true,
            (Err(TrialError::NonFinite(a)), Err(TrialError::NonFinite(b))) => {
                a.to_bits() == b.to_bits()
            }
            _ => false,
        };
        result_matches
            && back.trial.id == o.trial.id
            && back.trial.study == o.trial.study
            && bits_equal(&o.trial.x, &back.trial.x)
            && back.worker_id == o.worker_id
            && back.worker_seconds.to_bits() == o.worker_seconds.to_bits()
            && back.sim_cost_s.to_bits() == o.sim_cost_s.to_bits()
    });
}

#[test]
fn unsafe_integers_are_rejected_not_truncated() {
    // 2^53 is the first integer that collapses onto a float neighbor —
    // the PR-1 accessors refuse it, and the wire decoder inherits that
    for bad in ["9007199254740992", "9007199254740993", "1e300"] {
        let text = format!(r#"{{"id": {bad}, "round": 0, "x": [0.5], "attempt": 0}}"#);
        let j = Json::parse(&text).unwrap();
        assert!(Trial::from_json(&j).is_err(), "id {bad} must be rejected");
    }
    // 2^53 − 1 is the last safe id and must decode fine
    let j = Json::parse(r#"{"id": 9007199254740991, "round": 0, "x": [0.5], "attempt": 0}"#)
        .unwrap();
    assert_eq!(Trial::from_json(&j).unwrap().id, 9_007_199_254_740_991);
}

// ---------------------------------------------------------------------------
// loopback TCP integration
// ---------------------------------------------------------------------------

fn sphere_pool(seed: u64) -> SocketPool {
    SocketPool::listen(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed,
            policy: TrialPolicy::default(),
        },
    )
    .expect("bind loopback")
}

#[test]
fn loopback_workers_evaluate_trials() {
    let pool = sphere_pool(3);
    let addr = pool.local_addr().to_string();
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, 1).expect("worker run"))
        })
        .collect();
    pool.wait_for_capacity(2, Duration::from_secs(10)).unwrap();

    for id in 0..8 {
        pool.dispatch(Trial {
            id,
            study: StudyId::SOLO,
            round: 0,
            x: vec![0.5, -0.5, 0.0, 0.25, -0.25],
            attempt: 0,
        });
    }
    let mut ids = Vec::new();
    for _ in 0..8 {
        let o = pool.poll_outcome(Duration::from_secs(10)).expect("outcome before timeout");
        assert!(o.is_ok());
        // sphere5(0.5,-0.5,0,0.25,-0.25) = -(0.25+0.25+0+0.0625+0.0625)
        let v = o.result.unwrap().value;
        assert!((v + 0.625).abs() < 1e-12, "got {v}");
        ids.push(o.trial.id);
    }
    ids.sort_unstable();
    assert_eq!(ids, (0..8).collect::<Vec<_>>());

    let stats = pool.stats();
    assert_eq!(stats.backend, "tcp");
    assert_eq!(stats.links.len(), 2);
    assert_eq!(stats.links.iter().map(|l| l.completed).sum::<u64>(), 8);
    assert_eq!(stats.faults.requeued, 0);
    for l in &stats.links {
        assert!(l.bytes_tx > 0 && l.bytes_rx > 0, "wire bytes must be counted: {l:?}");
    }

    Box::new(pool).shutdown(); // sends Shutdown; workers exit
    for (i, h) in workers.into_iter().enumerate() {
        let summary = h.join().expect("worker thread");
        assert!(summary.evaluated <= 8, "worker {i} over-reported");
    }
}

#[test]
fn worker_disconnect_mid_trial_requeues_and_completes() {
    let pool = sphere_pool(5);
    let addr = pool.local_addr().to_string();

    // a hand-rolled worker that accepts one trial and then dies
    let mut fake = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut fake,
        &WorkerMsg::Hello { protocol: PROTOCOL_VERSION, capacity: 1, resume: None }.to_json(),
    )
    .unwrap();
    let (welcome, _) = read_frame(&mut fake).unwrap();
    assert!(matches!(LeaderMsg::from_json(&welcome).unwrap(), LeaderMsg::Welcome { .. }));
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();

    pool.dispatch(Trial {
        id: 7,
        study: StudyId::SOLO,
        round: 0,
        x: vec![0.1, 0.2, 0.3, 0.4, 0.5],
        attempt: 0,
    });
    let (msg, _) = read_frame(&mut fake).unwrap();
    assert!(matches!(LeaderMsg::from_json(&msg).unwrap(), LeaderMsg::Dispatch(_)));
    drop(fake); // crash mid-trial: the outcome will never come from here

    // a healthy worker joins and must pick the requeued trial up
    let addr2 = addr.clone();
    let rescuer = std::thread::spawn(move || run_worker(&addr2, 1).expect("rescuer run"));
    let o = pool.poll_outcome(Duration::from_secs(20)).expect("requeued trial must complete");
    assert_eq!(o.trial.id, 7, "the exact in-flight trial must be rescued");
    assert!(o.is_ok());

    let stats = pool.stats();
    assert_eq!(stats.faults.requeued, 1, "one in-flight trial was rescued: {stats:?}");

    Box::new(pool).shutdown();
    let summary = rescuer.join().unwrap();
    assert_eq!(summary.evaluated, 1);
}

#[test]
fn async_bo_runs_unchanged_over_loopback_tcp() {
    // the acceptance contract of the Transport refactor: AsyncBo against a
    // SocketPool behaves exactly like AsyncBo against threads — same
    // observation semantics, fantasies fully unwound at the end
    let pool = SocketPool::listen(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "levy2".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed: 9,
            policy: TrialPolicy::default(),
        },
    )
    .unwrap();
    let addr = pool.local_addr().to_string();
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, 1).expect("worker run"))
        })
        .collect();
    pool.wait_for_capacity(2, Duration::from_secs(10)).unwrap();

    let bo = BoConfig::lazy().with_seed(17).with_init(InitDesign::Lhs(4));
    let obj: Arc<dyn lazygp::objectives::Objective> =
        Arc::from(lazygp::objectives::by_name("levy2").unwrap());
    let mut abo = AsyncBo::with_transport(
        bo,
        obj,
        Box::new(pool),
        AsyncCoordinatorConfig {
            pending: PendingStrategy::ConstantLiarMin,
            ..Default::default()
        },
    );
    let best = abo.run_until_evals(16).unwrap();
    assert!(best.value.is_finite());
    assert_eq!(abo.driver().history().len(), 16);
    assert_eq!(abo.driver().surrogate().len(), 16);
    assert_eq!(abo.driver().fantasies_active(), 0);
    let s = abo.stats();
    assert_eq!(s.fantasies_issued, s.fantasy_rollbacks);
    let transport = abo.transport_stats();
    assert_eq!(transport.backend, "tcp");
    assert_eq!(transport.links.iter().map(|l| l.completed).sum::<u64>(), 12); // 16 − 4 seeds
    abo.finish();
    for h in workers {
        h.join().unwrap();
    }
}

#[test]
fn socket_pool_teardown_is_prompt() {
    // a worker sleeping out simulated cost must not delay pool shutdown:
    // run_worker's pool interrupts its sleep on Shutdown/EOF
    let pool = SocketPool::listen(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "resnet_cifar10".into(),
            sleep_scale: 1.0, // ~190 s simulated ⇒ capped 5 s real sleep
            fail_prob: 0.0,
            seed: 11,
            policy: TrialPolicy::default(),
        },
    )
    .unwrap();
    let addr = pool.local_addr().to_string();
    let worker = {
        let addr = addr.clone();
        std::thread::spawn(move || run_worker(&addr, 1))
    };
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();
    pool.dispatch(Trial {
        id: 0,
        study: StudyId::SOLO,
        round: 0,
        x: vec![0.05, 5e-4, 0.9],
        attempt: 0,
    });
    // give the worker time to start the trial and enter its sleep
    std::thread::sleep(Duration::from_millis(300));

    let t0 = Instant::now();
    Box::new(pool).shutdown();
    let teardown = t0.elapsed();
    assert!(
        teardown < Duration::from_secs(3),
        "leader teardown took {teardown:?} — worker sleep not interrupted"
    );
    let _ = worker.join().unwrap(); // worker exits promptly too (Err is fine: leader vanished)
}
