//! Integration tests for the PJRT runtime: artifact load, execute, and
//! numerical parity between the compiled XLA path and the native
//! Rust path (f64).
//!
//! These tests need `artifacts/` built by `make artifacts`; they skip
//! (with a note) when it is absent so `cargo test` works in a fresh
//! checkout.

use lazygp::acquisition::functions::Ei;
use lazygp::gp::lazy::LazyGp;
use lazygp::gp::Surrogate;
use lazygp::runtime::{score_native, GpScorer, PjrtRuntime};
use lazygp::util::rng::Pcg64;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime integration test: run `make artifacts` first");
        None
    }
}

fn trained_gp(rng: &mut Pcg64, n: usize, d: usize) -> LazyGp {
    let mut gp = LazyGp::paper_default();
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let y = x.iter().map(|v| (v * 0.7).sin()).sum::<f64>();
        gp.observe(&x, y);
    }
    gp
}

// Without the `xla` feature the runtime stub never offers a bucket (every
// request routes to the native scorer), so the execute and parity tests
// below would either panic on `bucket_for(..).expect(..)` or degenerate to
// comparing the native path with itself — they only mean something with
// the real PJRT client compiled in.
#[cfg(feature = "xla")]
#[test]
fn artifact_loads_and_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let bucket = rt.bucket_for(10, 2).expect("bucket for (10, 2)").clone();
    let n = bucket.n;
    let m = bucket.m;
    // trivial state: one observation at the origin, identity-padded L
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        l[i * n + i] = 1.0;
    }
    // first row is the real factor: L00 = sqrt(1 + noise) ≈ 1
    let mut mask = vec![0.0f64; n];
    mask[0] = 1.0;
    let mut alpha = vec![0.0f64; n];
    alpha[0] = 0.5;
    let x_train = vec![0.0f64; n * 2];
    let cand = vec![0.1f64; m * 2];
    let (mu, var, ei) = rt
        .run_gp_score(&bucket, &x_train, &l, &alpha, &mask, &cand, 0.0, 0.01, 0.0)
        .unwrap();
    assert_eq!(mu.len(), m);
    assert_eq!(var.len(), m);
    assert_eq!(ei.len(), m);
    assert!(mu.iter().all(|v| v.is_finite()));
    assert!(var.iter().all(|v| (0.0..=1.01).contains(v)));
    assert!(ei.iter().all(|v| *v >= 0.0));
}

#[cfg(feature = "xla")]
#[test]
fn xla_scores_match_native_f64() {
    let Some(dir) = artifacts_dir() else { return };
    let scorer = GpScorer::new(PjrtRuntime::new(dir).unwrap());
    let mut rng = Pcg64::new(161);
    for (n, d) in [(5usize, 2usize), (40, 3), (90, 5), (130, 2)] {
        let gp = trained_gp(&mut rng, n, d);
        let best = gp.incumbent().unwrap().1;
        let acq = Ei { xi: 0.01 };
        let cands: Vec<Vec<f64>> =
            (0..100).map(|_| (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect()).collect();
        let xla = scorer.score_batch(&gp, &acq, best, 0.01, &cands).unwrap();
        let native = score_native(&gp, &acq, best, &cands);
        for (i, (a, b)) in xla.iter().zip(&native).enumerate() {
            assert!(
                (a.mean - b.mean).abs() < 1e-8,
                "(n={n},d={d}) cand {i}: mean {} vs {}",
                a.mean,
                b.mean
            );
            assert!(
                (a.variance - b.variance).abs() < 1e-8,
                "(n={n},d={d}) cand {i}: var {} vs {}",
                a.variance,
                b.variance
            );
            // EI tolerance is looser than mean/var: the Pallas kernel uses
            // the Abramowitz–Stegun erf expansion (|err| < 1.5e-7; the erf
            // opcode is unparseable by xla_extension 0.5.1)
            assert!(
                (a.ei - b.ei).abs() < 1e-5,
                "(n={n},d={d}) cand {i}: ei {} vs {}",
                a.ei,
                b.ei
            );
        }
    }
    let (xla_calls, native_calls) = scorer.call_counts();
    assert!(xla_calls >= 4, "xla path must have served these: {xla_calls}");
    assert_eq!(native_calls, 0);
}

#[test]
fn oversized_state_falls_back_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let scorer = GpScorer::new(PjrtRuntime::new(dir).unwrap());
    let mut rng = Pcg64::new(163);
    // d=7 has no bucket
    let gp = trained_gp(&mut rng, 12, 7);
    let best = gp.incumbent().unwrap().1;
    let acq = Ei { xi: 0.01 };
    let cands: Vec<Vec<f64>> =
        (0..10).map(|_| (0..7).map(|_| rng.uniform(-3.0, 3.0)).collect()).collect();
    let scores = scorer.score_batch(&gp, &acq, best, 0.01, &cands).unwrap();
    assert_eq!(scores.len(), 10);
    let (_, native_calls) = scorer.call_counts();
    assert_eq!(native_calls, 1);
}

#[test]
fn chunking_covers_large_candidate_sets() {
    let Some(dir) = artifacts_dir() else { return };
    let scorer = GpScorer::new(PjrtRuntime::new(dir).unwrap());
    let mut rng = Pcg64::new(167);
    let gp = trained_gp(&mut rng, 20, 2);
    let best = gp.incumbent().unwrap().1;
    let acq = Ei { xi: 0.01 };
    // 300 candidates > M=128 ⇒ 3 chunks
    let cands: Vec<Vec<f64>> =
        (0..300).map(|_| vec![rng.uniform(-3.0, 3.0), rng.uniform(-3.0, 3.0)]).collect();
    let xla = scorer.score_batch(&gp, &acq, best, 0.01, &cands).unwrap();
    assert_eq!(xla.len(), 300);
    let native = score_native(&gp, &acq, best, &cands);
    for (a, b) in xla.iter().zip(&native) {
        assert!((a.ei - b.ei).abs() < 1e-5);
    }
}

#[cfg(feature = "xla")]
#[test]
fn executable_cache_is_reused() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(dir).unwrap();
    let bucket = rt.bucket_for(10, 3).unwrap().clone();
    let t0 = std::time::Instant::now();
    let _e1 = rt.executable(&bucket).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _e2 = rt.executable(&bucket).unwrap();
    let warm = t1.elapsed();
    assert!(warm < cold / 5, "cache miss? cold={cold:?} warm={warm:?}");
}
