//! Integration tests for the ranked-lock order enforcement in
//! `util::sync`, driven through the crate's public API.
//!
//! Two faces, selected by build profile:
//!
//! * **Checked** (`cargo test`, or `--features lock-order` in release):
//!   an inverted acquisition must panic deterministically, and the
//!   diagnostic must name both the offending rank and the held stack.
//! * **Passthrough** (`cargo test --release`): the ranked types must be
//!   layout-identical to their `std::sync` counterparts — the zero-cost
//!   claim in `docs/ARCHITECTURE.md`, asserted rather than assumed.

use lazygp::util::sync::{poison_recoveries, LockRank, RankedCondvar, RankedMutex, RankedRwLock};
use std::time::Duration;

/// Ascending acquisition through several ranks is always legal,
/// whichever imp is compiled in.
#[test]
fn ascending_chain_is_legal() {
    let fleet = RankedMutex::new(LockRank::Fleet, "t.fleet", 1u64);
    let queue = RankedMutex::new(LockRank::TrialQueue, "t.queue", 2u64);
    let stats = RankedRwLock::new(LockRank::StudyState, "t.stats", 3u64);
    let signal = RankedMutex::new(LockRank::Signal, "t.signal", 4u64);

    let a = fleet.lock();
    let b = queue.lock();
    let c = stats.read();
    let d = signal.lock();
    assert_eq!(*a + *b + *c + *d, 10);
}

/// Re-acquiring after a full release is legal: the order constrains
/// *simultaneously held* locks, not the lifetime acquisition sequence.
#[test]
fn release_then_lower_rank_is_legal() {
    let high = RankedMutex::new(LockRank::Metrics, "t.high", ());
    let low = RankedMutex::new(LockRank::Scheduler, "t.low", ());
    drop(high.lock());
    drop(low.lock());
    drop(high.lock());
}

/// The condvar round-trip returns a usable guard and reports timeouts.
#[test]
fn condvar_wait_timeout_roundtrip() {
    let m = RankedMutex::new(LockRank::TrialQueue, "t.cv_queue", 0u32);
    let cv = RankedCondvar::new();
    let guard = m.lock();
    let (mut guard, timed_out) = cv.wait_timeout(guard, Duration::from_millis(5));
    assert!(timed_out);
    *guard += 1;
    drop(guard);
    assert_eq!(*m.lock(), 1);
}

/// `try_lock` on a contended mutex returns `None` without corrupting
/// the held-rank bookkeeping (a later ordered lock still succeeds).
#[test]
fn try_lock_contended_returns_none() {
    let m = RankedMutex::new(LockRank::ConnList, "t.conns", ());
    let held = m.lock();
    assert!(m.try_lock().is_none());
    drop(held);
    assert!(m.try_lock().is_some());
}

/// The recovery counter is exposed and monotone from this crate's
/// public surface (transport metrics poll it).
#[test]
fn poison_counter_is_readable() {
    let before = poison_recoveries();
    let m = RankedMutex::new(LockRank::Metrics, "t.poison", 7u8);
    assert_eq!(*m.lock(), 7);
    assert!(poison_recoveries() >= before);
}

#[cfg(any(debug_assertions, feature = "lock-order"))]
mod checked {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn panic_message(f: impl FnOnce()) -> String {
        let err = catch_unwind(AssertUnwindSafe(f)).expect_err("expected a panic");
        err.downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| (*s).to_string()))
            .unwrap_or_else(|| "<non-string panic payload>".to_string())
    }

    /// The acceptance-bar test: acquiring a lower rank while holding a
    /// higher one panics, and the diagnostic names the offending rank,
    /// the held rank, and both registered lock names.
    #[test]
    fn inverted_acquisition_panics_with_diagnostic() {
        let arena = RankedMutex::new(LockRank::ScratchArena, "t.arena", ());
        let queue = RankedMutex::new(LockRank::TrialQueue, "t.queue", ());
        let msg = panic_message(|| {
            let _a = arena.lock();
            let _q = queue.lock(); // 6 after 13: inversion
        });
        assert!(msg.contains("lock-order violation"), "missing header: {msg}");
        assert!(msg.contains("TrialQueue"), "missing offending rank: {msg}");
        assert!(msg.contains("ScratchArena"), "missing held rank: {msg}");
        assert!(msg.contains("t.queue"), "missing offending name: {msg}");
        assert!(msg.contains("t.arena"), "missing held name: {msg}");
        assert!(msg.contains("ARCHITECTURE.md"), "missing doc pointer: {msg}");
    }

    /// The diagnostic reports the *full* held stack, not just the top.
    #[test]
    fn diagnostic_lists_full_held_stack() {
        let fleet = RankedMutex::new(LockRank::Fleet, "t.fleet", ());
        let conns = RankedMutex::new(LockRank::ConnList, "t.conns", ());
        let sched = RankedMutex::new(LockRank::Scheduler, "t.sched", ());
        let msg = panic_message(|| {
            let _f = fleet.lock();
            let _c = conns.lock();
            let _s = sched.lock(); // 1 after 0 < 7: inversion
        });
        assert!(msg.contains("t.fleet") && msg.contains("t.conns"), "stack incomplete: {msg}");
        assert!(msg.contains("Scheduler") && msg.contains("t.sched"), "offender missing: {msg}");
    }

    /// Same-rank reentrancy is an inversion too (ranks must *strictly*
    /// increase): two `LinkState` locks can never be held together.
    #[test]
    fn same_rank_reentrancy_panics() {
        let writer = RankedMutex::new(LockRank::LinkState, "t.writer", ());
        let in_flight = RankedMutex::new(LockRank::LinkState, "t.in_flight", ());
        let msg = panic_message(|| {
            let _w = writer.lock();
            let _i = in_flight.lock();
        });
        assert!(msg.contains("lock-order violation"), "missing header: {msg}");
        assert!(msg.contains("t.writer") && msg.contains("t.in_flight"), "names missing: {msg}");
    }

    /// RwLock read access participates in the same order as writes.
    #[test]
    fn rwlock_read_is_rank_checked() {
        let stats = RankedRwLock::new(LockRank::StudyState, "t.stats", ());
        let registry = RankedMutex::new(LockRank::StudyRegistry, "t.registry", ());
        let msg = panic_message(|| {
            let _s = stats.read();
            let _r = registry.lock(); // 4 after 10: inversion
        });
        assert!(msg.contains("StudyRegistry"), "missing offending rank: {msg}");
        assert!(msg.contains("StudyState"), "missing held rank: {msg}");
    }

    /// A rank held across a condvar wait still forbids lower
    /// acquisitions after the wait returns — the TLS entry survives the
    /// release/reacquire cycle inside `wait_timeout`.
    #[test]
    fn rank_survives_condvar_wait() {
        let queue = RankedMutex::new(LockRank::TrialQueue, "t.queue", ());
        let sched = RankedMutex::new(LockRank::Scheduler, "t.sched", ());
        let cv = RankedCondvar::new();
        let msg = panic_message(|| {
            let guard = queue.lock();
            let (_guard, _) = cv.wait_timeout(guard, Duration::from_millis(1));
            let _s = sched.lock(); // still holding TrialQueue: inversion
        });
        assert!(msg.contains("TrialQueue"), "rank lost across wait: {msg}");
    }

    /// The real `ShutdownToken` sits at the leaf (`Signal`), so it may
    /// be triggered while any other lock is held — the exact shape of
    /// the cancel path (`CancelTable.live` → token.trigger()).
    #[test]
    fn shutdown_token_is_a_legal_leaf() {
        use lazygp::coordinator::worker::ShutdownToken;
        let live = RankedMutex::new(LockRank::LinkState, "t.live", ());
        let token = ShutdownToken::default();
        let _l = live.lock();
        token.trigger();
        assert!(token.is_triggered());
        // Interrupted sleep reports `false` (did not run the full
        // duration) — and must return immediately.
        assert!(!token.sleep(Duration::from_millis(1)));
    }
}

/// Release-passthrough layout assertions: with the checks compiled out,
/// the ranked wrappers must cost nothing — same size as the std types
/// they wrap, and guards with no extra state. Compiled only when the
/// checked imp is off (release build without `--features lock-order`).
#[cfg(not(any(debug_assertions, feature = "lock-order")))]
mod passthrough {
    use super::*;
    use std::mem::size_of;
    use std::sync::{Condvar, Mutex, MutexGuard, RwLock};

    #[test]
    fn ranked_types_are_layout_free() {
        assert_eq!(size_of::<RankedMutex<u64>>(), size_of::<Mutex<u64>>());
        assert_eq!(size_of::<RankedMutex<Vec<u8>>>(), size_of::<Mutex<Vec<u8>>>());
        assert_eq!(size_of::<RankedRwLock<u64>>(), size_of::<RwLock<u64>>());
        assert_eq!(size_of::<RankedCondvar>(), size_of::<Condvar>());
    }

    #[test]
    fn guards_are_layout_free() {
        assert_eq!(
            size_of::<lazygp::util::sync::RankedMutexGuard<'static, u64>>(),
            size_of::<MutexGuard<'static, u64>>()
        );
    }
}
