//! Batch-acquisition integration suite: the hedged q-EI path
//! ([`BoDriver::suggest_batch_hedged`]) must propose *diverse* batches —
//! q=8 pairwise distinct under the normalized distance — and must not give
//! up optimization quality relative to the sequential driver on the same
//! evaluation budget. Also pins the automatic routing (`batch_hedged` in
//! [`BoConfig`]) and the fantasy hygiene of the hedged path under every
//! surrogate backend.

use lazygp::acquisition::topk::normalized_dist;
use lazygp::bo::driver::{BoConfig, BoDriver, InitDesign, PendingStrategy};
use lazygp::gp::SurrogateSpec;
use lazygp::objectives::levy::Levy;
use lazygp::util::rng::Pcg64;

fn levy2() -> Box<Levy> {
    Box::new(Levy::new(2))
}

fn seeded_driver(cfg: BoConfig) -> BoDriver {
    let mut d = BoDriver::new(cfg, levy2());
    d.ensure_seeded();
    // a few real steps so the acquisition surface has structure beyond the
    // initial design
    for _ in 0..4 {
        d.step();
    }
    d
}

#[test]
fn hedged_q8_is_pairwise_distinct() {
    for spec in [SurrogateSpec::Lazy { lag: 0 }, SurrogateSpec::Dngo { rff_dim: 64 }] {
        let mut d = seeded_driver(
            BoConfig::lazy().with_surrogate(spec).with_seed(5).with_init(InitDesign::Lhs(6)),
        );
        let batch = d.suggest_batch_hedged(8, PendingStrategy::ConstantLiarMin);
        assert_eq!(batch.len(), 8, "{spec:?}");
        assert_eq!(d.fantasies_active(), 0, "{spec:?}: hedging must clean up after itself");
        let bounds = d.objective().bounds().to_vec();
        for i in 0..batch.len() {
            for j in (i + 1)..batch.len() {
                let dist = normalized_dist(&batch[i], &batch[j], &bounds);
                assert!(
                    dist > 1e-6,
                    "{spec:?}: picks {i} and {j} coincide (dist {dist:.3e}): hedging failed \
                     to diversify {:?} vs {:?}",
                    batch[i],
                    batch[j]
                );
            }
        }
    }
}

#[test]
fn batch_hedged_config_routes_suggest_batch() {
    let mut hedged = seeded_driver(
        BoConfig::lazy().with_seed(7).with_init(InitDesign::Lhs(6)).with_hedged_batches(true),
    );
    let mut classic = seeded_driver(BoConfig::lazy().with_seed(7).with_init(InitDesign::Lhs(6)));
    let hb = hedged.suggest_batch(4);
    let cb = classic.suggest_batch(4);
    assert_eq!(hb.len(), 4);
    assert_eq!(cb.len(), 4);
    assert_eq!(hedged.fantasies_active(), 0);
    // same driver state, different batch construction: the hedged batch is
    // built against refantasized surfaces, so it diverges from the static
    // top-t maxima of the classic path
    assert_ne!(hb, cb, "hedged routing had no effect on the proposed batch");
    // t=1 short-circuits to the classic single suggest on both
    let h1 = hedged.suggest_batch(1);
    assert_eq!(h1.len(), 1);
    assert_eq!(hedged.fantasies_active(), 0);
}

#[test]
fn hedged_batches_match_solo_quality_on_levy2() {
    // same budget: solo runs 6 init + 32 sequential evals; the hedged arm
    // runs 6 init + 8 rounds of q=4 hedged batches
    let mut solo = BoDriver::new(
        BoConfig::lazy().with_seed(11).with_init(InitDesign::Lhs(6)),
        levy2(),
    );
    let solo_best = solo.run(32).value;

    let mut hedged = BoDriver::new(
        BoConfig::lazy().with_seed(11).with_init(InitDesign::Lhs(6)).with_hedged_batches(true),
        levy2(),
    );
    hedged.ensure_seeded();
    let init_best = hedged.best().expect("seeded").value;
    let mut eval_rng = Pcg64::new(1234);
    for _round in 0..8 {
        let batch = hedged.suggest_batch(4);
        for x in batch {
            let e = hedged.objective().eval(&x, &mut eval_rng);
            hedged.observe_external(x, e);
        }
    }
    let hedged_best = hedged.best().expect("ran").value;

    assert!(
        hedged_best >= init_best,
        "hedged best {hedged_best} lost ground vs its own init {init_best}"
    );
    // parity band: batched proposals may pay some per-round redundancy but
    // must stay in the same quality regime as the sequential driver
    assert!(
        hedged_best >= solo_best - 2.0,
        "hedged q-EI fell out of the solo quality band: {hedged_best} vs solo {solo_best}"
    );
}

#[test]
fn hedged_path_works_under_every_backend() {
    for (spec, tag) in [
        (SurrogateSpec::Lazy { lag: 0 }, "lazy"),
        (SurrogateSpec::Exact, "exact"),
        (SurrogateSpec::Dngo { rff_dim: 32 }, "dngo"),
    ] {
        let mut d = seeded_driver(
            BoConfig::lazy().with_surrogate(spec).with_seed(13).with_init(InitDesign::Lhs(5)),
        );
        let batch = d.suggest_batch_hedged(3, PendingStrategy::PosteriorMean);
        assert_eq!(batch.len(), 3, "{tag}");
        assert_eq!(d.fantasies_active(), 0, "{tag}");
        for x in &batch {
            assert!(x.iter().all(|v| v.is_finite()), "{tag}: non-finite pick {x:?}");
        }
    }
}
