//! Deterministic chaos harness for evaluation-fault tolerance: a scripted
//! [`FaultPlan`] (hangs, crashes, NaNs) drives multi-study runs to
//! completion with exact exactly-once accounting, hung trials are reaped
//! by the leader within 2× their deadline, a quarantined worker link sits
//! out its cool-down and rejoins through the half-open probe, and a
//! mid-chaos leader crash + journal resume is bitwise identical to a run
//! that never crashed.
//!
//! Every fault here is *scripted* — keyed by `(study, trial id)` — so the
//! suite is deterministic at any worker count. CI runs this file in its
//! own `chaos` job with `--test-threads=1` and a hard timeout;
//! `LAZYGP_CHAOS_DIR` pins the scratch directory so the journals of a
//! failed run can be uploaded as artifacts.

use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lazygp::acquisition::optim::OptimConfig;
use lazygp::bo::driver::{Best, BoConfig, InitDesign, PendingStrategy};
use lazygp::coordinator::transport::{
    read_frame, read_frame_with, write_frame, FrameConfig, LeaderMsg, Transport, WorkerMsg,
    PROTOCOL_VERSION,
};
use lazygp::coordinator::{
    journal_path, recover, snapshot_path, AsyncBo, AsyncCoordinatorConfig, FaultKind, FaultPlan,
    OpenInfo, RemoteEvalConfig, SocketPool, SocketPoolOptions, StudyId, StudyJournal,
    StudyService, StudySpec, Trial, TrialError, TrialOutcome, TrialPolicy, WorkerConfig,
    WorkerPool, JOURNAL_FORMAT,
};
use lazygp::gp::Surrogate;
use lazygp::objectives::{self, Evaluation};
use lazygp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// harness helpers
// ---------------------------------------------------------------------------

fn fast_bo(seed: u64) -> BoConfig {
    BoConfig::lazy()
        .with_seed(seed)
        .with_init(InitDesign::Lhs(5))
        .with_optim(OptimConfig { candidates: 96, restarts: 3, nm_iters: 20, nm_scale: 0.08 })
}

/// Scratch root for journals; CI pins it via `LAZYGP_CHAOS_DIR` so the
/// artifacts of a failed run can be uploaded.
fn scratch_root() -> PathBuf {
    match std::env::var("LAZYGP_CHAOS_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir().join("lazygp_chaos"),
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = scratch_root().join(format!("{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Wait until `cond` holds or `timeout` passes; returns the elapsed time
/// on success.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> Option<Duration> {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return Some(t0.elapsed());
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    None
}

fn trial(id: u64) -> Trial {
    Trial { id, study: StudyId::SOLO, round: 0, x: vec![0.1, -0.2, 0.3, 0.0, -0.1], attempt: 0 }
}

/// Leader options with heartbeats off — these tests manage scripted peers
/// explicitly and must not race the link reaper.
fn quiet_options() -> SocketPoolOptions {
    SocketPoolOptions {
        heartbeat_interval: Duration::ZERO,
        worker_loss_deadline: Duration::ZERO,
        ..Default::default()
    }
}

fn sphere_pool(policy: TrialPolicy, options: SocketPoolOptions) -> SocketPool {
    SocketPool::listen_with(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed: 3,
            policy,
        },
        options,
    )
    .expect("bind loopback")
}

/// A hand-rolled scripted worker: speaks the real handshake, then reads
/// and writes raw frames exactly when told to — or wedges silently.
struct ScriptedWorker {
    stream: TcpStream,
}

impl ScriptedWorker {
    fn connect(addr: SocketAddr, capacity: usize) -> ScriptedWorker {
        let mut stream = TcpStream::connect(addr).expect("connect scripted worker");
        write_frame(
            &mut stream,
            &WorkerMsg::Hello { protocol: PROTOCOL_VERSION, capacity, resume: None }.to_json(),
        )
        .expect("send hello");
        let (welcome, _) = read_frame(&mut stream).expect("read welcome");
        assert!(
            matches!(LeaderMsg::from_json(&welcome), Ok(LeaderMsg::Welcome { .. })),
            "expected welcome"
        );
        ScriptedWorker { stream }
    }

    /// Next leader frame within `timeout`, if any.
    fn read_msg(&mut self, timeout: Duration) -> Option<LeaderMsg> {
        self.stream.set_read_timeout(Some(timeout)).unwrap();
        let (json, _) = read_frame(&mut self.stream).ok()?;
        LeaderMsg::from_json(&json).ok()
    }

    /// Next dispatched trial within `timeout`, if any (skips nothing: a
    /// non-Dispatch frame is a test failure surfaced as `None`).
    fn read_trial(&mut self, timeout: Duration) -> Option<Trial> {
        match self.read_msg(timeout)? {
            LeaderMsg::Dispatch(t) => Some(t),
            _ => None,
        }
    }

    fn send_outcome(&mut self, t: &Trial) {
        let outcome = TrialOutcome {
            trial: t.clone(),
            worker_id: 0,
            result: Ok(Evaluation { value: 1.0, sim_cost_s: 1.0 }),
            worker_seconds: 0.0,
            sim_cost_s: 1.0,
        };
        let _ = write_frame(&mut self.stream, &WorkerMsg::Outcome(outcome).to_json());
    }

    fn send_error(&mut self, t: &Trial, err: TrialError) {
        let outcome = TrialOutcome {
            trial: t.clone(),
            worker_id: 0,
            result: Err(err),
            worker_seconds: 0.0,
            sim_cost_s: 0.05,
        };
        let _ = write_frame(&mut self.stream, &WorkerMsg::Outcome(outcome).to_json());
    }
}

// ---------------------------------------------------------------------------
// scripted two-study chaos run: exact exactly-once accounting
// ---------------------------------------------------------------------------

/// Two studies share a thread fleet while a scripted plan crashes, NaNs
/// and hangs one first-attempt trial each per study. Every fault is
/// retried onto a fresh trial id outside the plan, so both studies must
/// complete their full budget, and the per-study ledgers must reconcile
/// exactly: dispatched == completed == budget + the three scripted
/// faults, with nothing requeued, duplicated, or lost.
#[test]
fn two_studies_complete_their_budget_under_scripted_faults() {
    const EVALS: usize = 8;
    // per-study trial ids under slots=1 are sequential; ids 1, 4, 7 are
    // first attempts (their retries land on ids 2, 5, 8 — unscripted)
    let plan = FaultPlan::new()
        .with(StudyId(1), 1, FaultKind::Crash)
        .with(StudyId(1), 4, FaultKind::NaN)
        .with(StudyId(1), 7, FaultKind::Hang)
        .with(StudyId(2), 1, FaultKind::Crash)
        .with(StudyId(2), 4, FaultKind::NaN)
        .with(StudyId(2), 7, FaultKind::Hang);
    let base: Arc<dyn objectives::Objective> =
        Arc::from(objectives::by_name("sphere5").unwrap());
    let fleet = WorkerPool::spawn(
        base,
        WorkerConfig { workers: 2, seed: 5, fault_plan: plan, ..WorkerConfig::default() },
    );
    let service = StudyService::new(Box::new(fleet));
    // the deadline is what turns a scripted hang into a worker-side
    // Timeout instead of a wedged slot
    let policy = TrialPolicy { deadline_s: 0.05, ..TrialPolicy::default() };
    let a = service
        .create_study(
            StudySpec::new("chaos-a", "sphere5")
                .with_bo(fast_bo(11))
                .with_evals(EVALS)
                .with_policy(policy),
        )
        .unwrap();
    let b = service
        .create_study(
            StudySpec::new("chaos-b", "levy2")
                .with_bo(fast_bo(23))
                .with_evals(EVALS)
                .with_policy(policy),
        )
        .unwrap();
    assert_eq!((a, b), (StudyId(1), StudyId(2)), "the fault plan is keyed by these ids");

    let result_a = service.wait(a).unwrap();
    let result_b = service.wait(b).unwrap();
    for (id, result) in [(a, &result_a), (b, &result_b)] {
        let best = result.best.as_ref().unwrap_or_else(|| panic!("study {id} found no best"));
        assert!(best.value.is_finite(), "study {id} best is not finite");
    }

    let stats = service.stats();
    assert_eq!(stats.faults.timeouts, 2, "one reaped hang per study: {:?}", stats.faults);
    for id in [a, b] {
        let row = stats.studies.iter().find(|r| r.study == id.0).expect("study row");
        assert_eq!(
            row.dispatched,
            (EVALS + 3) as u64,
            "study {id}: budget + one retry per scripted fault"
        );
        assert_eq!(row.completed, row.dispatched, "study {id}: every attempt settled");
        assert_eq!(row.requeued, 0, "study {id}");
        assert_eq!(row.duplicates_dropped, 0, "study {id}");
    }
    service.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// leader-side reaper: a wedged remote attempt is cancelled at 2× deadline
// ---------------------------------------------------------------------------

/// A scripted TCP worker accepts a trial and never responds — the
/// worker-side deadline cannot fire because the worker is wedged. The
/// leader's reaper must cancel the attempt once it overruns 2× the
/// deadline (never earlier), requeue it through the exactly-once gate,
/// and the re-dispatched attempt must complete exactly once.
#[test]
fn hung_remote_trial_is_reaped_within_twice_its_deadline() {
    const DEADLINE_S: f64 = 0.1;
    let pool = sphere_pool(
        TrialPolicy { deadline_s: DEADLINE_S, ..TrialPolicy::default() },
        quiet_options(),
    );
    let addr = pool.local_addr();
    let mut wedged = ScriptedWorker::connect(addr, 1);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();

    let t0 = Instant::now();
    pool.dispatch(trial(0));
    let t = wedged.read_trial(Duration::from_secs(10)).expect("dispatch arrives");
    assert_eq!(t.id, 0);
    // ...and the worker goes silent. The reaper fires at 2× deadline
    // (+ its 100 ms sweep cadence and CI scheduling slack), not before.
    wait_until(Duration::from_secs(5), || pool.stats().faults.cancels >= 1)
        .expect("reaper must cancel the overdue attempt");
    let elapsed = t0.elapsed();
    assert!(
        elapsed >= Duration::from_secs_f64(2.0 * DEADLINE_S),
        "reaped too early: {elapsed:?}"
    );
    assert!(
        elapsed <= Duration::from_secs_f64(2.0 * DEADLINE_S + 1.0),
        "reaped too late: {elapsed:?}"
    );
    let stats = pool.stats();
    assert!(stats.faults.requeued >= 1, "the reaped trial must be requeued: {:?}", stats.faults);

    // the wedged link first sees the best-effort Cancel, then — being the
    // only worker — the requeued re-dispatch; answering it completes the
    // trial exactly once
    match wedged.read_msg(Duration::from_secs(5)).expect("cancel frame") {
        LeaderMsg::Cancel { trial, .. } => assert_eq!(trial, 0),
        other => panic!("expected Cancel, got {other:?}"),
    }
    let again = wedged.read_trial(Duration::from_secs(5)).expect("requeued re-dispatch");
    assert_eq!(again.id, 0);
    wedged.send_outcome(&again);
    let o = pool.poll_outcome(Duration::from_secs(10)).expect("re-dispatched trial completes");
    assert_eq!(o.trial.id, 0);
    assert!(o.is_ok());
    assert!(pool.poll_outcome(Duration::from_millis(300)).is_none(), "no duplicate outcome");
    Box::new(pool).shutdown();
}

// ---------------------------------------------------------------------------
// circuit breaker: quarantine, cool-down, half-open probe, rejoin
// ---------------------------------------------------------------------------

/// Two consecutive failures trip the leader-side breaker: the link's
/// capacity leaves the fleet, it receives no trials during its cool-down,
/// then exactly one half-open probe — and a successful probe rejoins it.
#[test]
fn quarantined_worker_sits_out_cooldown_and_rejoins_via_probe() {
    let cooldown = Duration::from_millis(400);
    let pool = sphere_pool(
        TrialPolicy::default(),
        SocketPoolOptions {
            heartbeat_interval: Duration::ZERO,
            worker_loss_deadline: Duration::ZERO,
            quarantine_after: 2,
            quarantine_cooldown: cooldown,
            ..Default::default()
        },
    );
    let addr = pool.local_addr();
    let mut flaky = ScriptedWorker::connect(addr, 1);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();

    // two consecutive failures trip the breaker
    for id in 0..2 {
        pool.dispatch(trial(id));
        let t = flaky.read_trial(Duration::from_secs(10)).expect("dispatch arrives");
        flaky.send_error(&t, TrialError::SimulatedCrash);
        let o = pool.poll_outcome(Duration::from_secs(10)).expect("failure delivered");
        assert!(!o.is_ok());
    }
    wait_until(Duration::from_secs(5), || pool.stats().faults.quarantines >= 1)
        .expect("breaker must trip after 2 consecutive failures");
    assert_eq!(pool.capacity_now(), 0, "quarantined capacity leaves the fleet");

    // a trial dispatched during the cool-down must not reach the worker…
    let quarantined_at = Instant::now();
    pool.dispatch(trial(2));
    assert!(
        flaky.read_trial(cooldown / 2).is_none(),
        "no dispatch may reach a quarantined worker during its cool-down"
    );
    // …but once the cool-down elapses it arrives as the half-open probe
    let probe = flaky.read_trial(Duration::from_secs(5)).expect("half-open probe");
    assert_eq!(probe.id, 2);
    assert!(
        quarantined_at.elapsed() >= cooldown / 2,
        "probe arrived before the cool-down elapsed"
    );
    flaky.send_outcome(&probe);
    let o = pool.poll_outcome(Duration::from_secs(10)).expect("probe outcome");
    assert!(o.is_ok());

    // a successful probe rejoins the link: capacity is back and trials
    // flow immediately again
    wait_until(Duration::from_secs(5), || pool.capacity_now() == 1)
        .expect("successful probe must rejoin the worker");
    pool.dispatch(trial(3));
    let t = flaky.read_trial(Duration::from_secs(5)).expect("post-rejoin dispatch");
    flaky.send_outcome(&t);
    assert!(pool.poll_outcome(Duration::from_secs(10)).is_some());
    assert_eq!(pool.stats().faults.quarantines, 1, "the breaker tripped exactly once");
    Box::new(pool).shutdown();
}

// ---------------------------------------------------------------------------
// mid-chaos leader crash + resume is bitwise identical
// ---------------------------------------------------------------------------

/// Everything a run must reproduce bitwise after a crash (deliberately
/// excludes `virtual_done_s`, which embeds real leader seconds).
#[derive(Debug, Clone, PartialEq, Eq)]
struct RunFacts {
    trial_ids: Vec<u64>,
    best_trace_bits: Vec<u64>,
    best_value_bits: u64,
    best_x_bits: Vec<u64>,
    posterior_digest: u64,
    rng_draws: u64,
    failed_imputations: usize,
}

fn facts(abo: &AsyncBo, best: &Best) -> RunFacts {
    RunFacts {
        trial_ids: abo.events().iter().map(|e| e.trial_id).collect(),
        best_trace_bits: abo.events().iter().map(|e| e.best.to_bits()).collect(),
        best_value_bits: best.value.to_bits(),
        best_x_bits: best.x.iter().map(|v| v.to_bits()).collect(),
        posterior_digest: abo.driver().surrogate().state_digest(),
        rng_draws: abo.driver().rng().draws(),
        failed_imputations: abo.driver().failed_observations(),
    }
}

/// Single attempt per trial: every scripted fault is terminal, so the
/// crash-penalty imputation path runs (and is journaled) for each one.
fn chaos_policy() -> TrialPolicy {
    TrialPolicy { deadline_s: 0.02, max_attempts: 1, retry_backoff_s: 0.0 }
}

/// Crash, NaN and hang three distinct first-and-only attempts. With
/// `max_attempts: 1` trial ids are sequential, so ids 2, 4, 6 are always
/// dispatched and always faulted — the run is chaos-deterministic.
fn chaos_plan() -> FaultPlan {
    FaultPlan::new()
        .with(StudyId::SOLO, 2, FaultKind::Crash)
        .with(StudyId::SOLO, 4, FaultKind::NaN)
        .with(StudyId::SOLO, 6, FaultKind::Hang)
}

fn chaos_open_info(seed: u64, evals: usize) -> OpenInfo {
    OpenInfo {
        format: JOURNAL_FORMAT,
        study: 0,
        name: "chaos".into(),
        objective: "sphere5".into(),
        seed,
        evals,
        slots: 1,
        pending: "cl-min".into(),
        max_retries: 0,
        surrogate: lazygp::gp::SurrogateSpec::default(),
        policy: chaos_policy(),
    }
}

/// Journaled (or not) solo chaos run over a thread fleet with the
/// scripted plan and failure-aware acquisition; resumes an existing
/// journal in the directory automatically.
fn chaos_run(journal_dir: Option<&Path>, seed: u64, evals: usize) -> RunFacts {
    let obj: Arc<dyn objectives::Objective> = Arc::from(objectives::by_name("sphere5").unwrap());
    let pool = WorkerPool::spawn(
        Arc::clone(&obj),
        WorkerConfig {
            workers: 1,
            seed: seed ^ 0x9e37_79b9_7f4a_7c15,
            policy: chaos_policy(),
            fault_plan: chaos_plan(),
            ..WorkerConfig::default()
        },
    );
    let config = AsyncCoordinatorConfig {
        workers: 1,
        pending: PendingStrategy::ConstantLiarMin,
        sleep_scale: 0.0,
        fail_prob: 0.0,
        max_retries: 0,
        seed,
        policy: chaos_policy(),
    };
    let bo = fast_bo(seed).with_crash_penalty(0.25);
    let mut abo = AsyncBo::with_transport(bo, obj, Box::new(pool), config);
    if let Some(dir) = journal_dir {
        let (journal, replay) = match recover(dir, "chaos").expect("recover repairable journal") {
            Some(rec) => {
                let entries = rec.entries.clone();
                let j = StudyJournal::resume(dir, &rec).expect("reattach").with_snapshot_every(3);
                (j, entries)
            }
            None => {
                let j = StudyJournal::create(dir, chaos_open_info(seed, evals))
                    .expect("create journal")
                    .with_snapshot_every(3);
                (j, Vec::new())
            }
        };
        abo = abo.with_journal(journal, replay);
    }
    let best = abo.run_until_evals(evals).expect("chaos run completes");
    let f = facts(&abo, &best);
    abo.finish();
    f
}

/// Offsets of every complete-frame boundary in `bytes` (0 included).
fn frame_boundaries(bytes: &[u8]) -> Vec<usize> {
    let cfg = FrameConfig { checksum: true, ..FrameConfig::default() };
    let mut offsets = vec![0usize];
    let mut slice: &[u8] = bytes;
    while !slice.is_empty() {
        if read_frame_with(&mut slice, &cfg).is_err() {
            break;
        }
        offsets.push(bytes.len() - slice.len());
    }
    offsets
}

/// Plant a (possibly truncated) journal copy and the golden snapshot in
/// a fresh directory, as left behind by a crash.
fn plant(dir: &Path, journal: &[u8], snapshot: Option<&[u8]>) {
    std::fs::write(journal_path(dir, "chaos"), journal).expect("plant journal");
    if let Some(s) = snapshot {
        std::fs::write(snapshot_path(dir, "chaos"), s).expect("plant snapshot");
    }
}

/// Kill the journaled leader at record boundaries and at random
/// mid-record byte offsets *while scripted faults and crash-penalty
/// imputations are in flight*, resume, and demand bitwise equality with
/// the uninterrupted chaos run. Also checks that neither journaling nor
/// the chaos machinery itself perturbs the decision stream.
#[test]
fn mid_chaos_crash_and_resume_is_bitwise_identical() {
    const SEED: u64 = 77;
    const EVALS: usize = 9;
    let golden_dir = fresh_dir("chaos_golden");
    let golden = chaos_run(Some(&golden_dir), SEED, EVALS);
    assert_eq!(
        golden.failed_imputations, 3,
        "all three scripted faults must be terminal and imputed"
    );

    let plain = chaos_run(None, SEED, EVALS);
    assert_eq!(golden, plain, "journaling must not perturb the chaos run");

    let journal = std::fs::read(journal_path(&golden_dir, "chaos")).expect("golden journal");
    let snapshot = std::fs::read(snapshot_path(&golden_dir, "chaos")).ok();

    // every 3rd record boundary plus a few mid-record tears keeps the
    // sweep representative without resuming dozens of runs
    let mut cuts: Vec<usize> = frame_boundaries(&journal).into_iter().step_by(3).collect();
    let mut rng = Pcg64::new(0xC0A5);
    for _ in 0..4 {
        cuts.push((rng.next_u64() % journal.len() as u64) as usize);
    }
    for (i, &cut) in cuts.iter().enumerate() {
        let dir = fresh_dir(&format!("chaos_cut_{i}"));
        plant(&dir, &journal[..cut], snapshot.as_deref());
        let resumed = chaos_run(Some(&dir), SEED, EVALS);
        assert_eq!(resumed, golden, "resume after a crash at journal byte {cut} diverged");
    }
}
