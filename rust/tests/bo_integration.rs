//! End-to-end BO integration tests: full optimization runs across
//! surrogates and objectives, asserting the paper's qualitative claims at
//! test scale (lazy ≡ exact posterior when frozen; lazy much cheaper per
//! iteration as n grows; both optimize).

use lazygp::acquisition::optim::OptimConfig;
use lazygp::bo::driver::{BoConfig, BoDriver, InitDesign};
use lazygp::objectives::levy::Levy;
use lazygp::objectives::suite::{Branin, Hartmann6};
use lazygp::objectives::trainer::{LeNetMnistSim, ResNetCifarSim};

fn fast() -> OptimConfig {
    OptimConfig { candidates: 128, restarts: 3, nm_iters: 25, nm_scale: 0.08 }
}

#[test]
fn lazy_bo_converges_on_levy2() {
    let cfg = BoConfig::lazy()
        .with_seed(7)
        .with_init(InitDesign::Lhs(10))
        .with_optim(fast());
    let mut d = BoDriver::new(cfg, Box::new(Levy::new(2)));
    let best = d.run(60);
    // global max is 0; Levy-2D should get close in 60 iterations
    assert!(best.value > -1.0, "levy2 best={}", best.value);
}

#[test]
fn exact_and_lazy_improve_comparably_on_branin() {
    let run = |cfg: BoConfig| {
        let mut d = BoDriver::new(
            cfg.with_seed(11).with_init(InitDesign::Lhs(8)).with_optim(fast()),
            Box::new(Branin::new()),
        );
        d.run(30).value
    };
    let lazy = run(BoConfig::lazy());
    let exact = run(BoConfig::exact());
    // both should be in the basin (optimum ≈ −0.398); neither should be
    // catastrophically worse
    assert!(lazy > -3.0, "lazy={lazy}");
    assert!(exact > -3.0, "exact={exact}");
}

#[test]
fn lazy_gp_updates_are_much_cheaper_than_exact_at_scale() {
    // the paper's Fig. 1 claim, at test scale: run 120 iterations on a
    // cheap objective; the exact GP re-fits + refactorizes every step
    let run = |cfg: BoConfig| {
        let mut d = BoDriver::new(
            cfg.with_seed(13).with_init(InitDesign::Lhs(5)).with_optim(fast()),
            Box::new(Levy::new(3)),
        );
        d.run(120);
        d.gp_seconds_total()
    };
    let lazy_s = run(BoConfig::lazy());
    let exact_s = run(BoConfig::exact());
    assert!(
        exact_s > 3.0 * lazy_s,
        "expected exact ≫ lazy GP time: exact={exact_s:.4}s lazy={lazy_s:.4}s"
    );
}

#[test]
fn lagged_variant_sits_between() {
    let gp_time = |cfg: BoConfig| {
        let mut d = BoDriver::new(
            cfg.with_seed(17).with_init(InitDesign::Lhs(5)).with_optim(fast()),
            Box::new(Levy::new(3)),
        );
        d.run(80);
        d.gp_seconds_total()
    };
    let lazy = gp_time(BoConfig::lazy());
    let lag10 = gp_time(BoConfig::lazy_lagged(10));
    let exact = gp_time(BoConfig::exact());
    assert!(lazy <= lag10 * 1.5, "lazy={lazy} lag10={lag10}");
    assert!(lag10 < exact, "lag10={lag10} exact={exact}");
}

#[test]
fn hpo_simulators_are_optimizable() {
    let cfg = BoConfig::lazy()
        .with_seed(19)
        .with_init(InitDesign::Lhs(10))
        .with_optim(fast());
    let mut d = BoDriver::new(cfg, Box::new(LeNetMnistSim::new()));
    let best = d.run(60);
    assert!(best.value > 0.9, "lenet best acc={}", best.value);

    let cfg = BoConfig::lazy()
        .with_seed(23)
        .with_init(InitDesign::Lhs(10))
        .with_optim(fast());
    let mut d = BoDriver::new(cfg, Box::new(ResNetCifarSim::new()));
    let best = d.run(60);
    assert!(best.value > 0.75, "resnet best acc={}", best.value);
}

#[test]
fn hartmann6_reaches_reasonable_value() {
    let cfg = BoConfig::lazy()
        .with_seed(29)
        .with_init(InitDesign::Lhs(15))
        .with_optim(OptimConfig { candidates: 256, restarts: 5, nm_iters: 40, nm_scale: 0.08 });
    let mut d = BoDriver::new(cfg, Box::new(Hartmann6::new()));
    let best = d.run(70);
    // optimum 3.322; random search rarely beats 2.5 in 85 evals
    assert!(best.value > 2.0, "hartmann6 best={}", best.value);
}

#[test]
fn surrogate_observation_count_tracks_history() {
    let cfg = BoConfig::lazy().with_seed(31).with_init(InitDesign::Random(4)).with_optim(fast());
    let mut d = BoDriver::new(cfg, Box::new(Levy::new(2)));
    d.run(10);
    assert_eq!(d.surrogate().len(), 14);
    assert_eq!(d.history().len(), 14);
}
