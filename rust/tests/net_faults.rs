//! Fault-injection suite for the hardened TCP transport: corrupted and
//! mid-frame-truncated traffic, frozen (SIGSTOP-style) peers reaped by
//! heartbeats, leader crash + restart with worker reconnect, crossed
//! outcome/requeue races de-duplicated by the delivery gate, and a
//! property test that trial-id delivery to the coordinator is exactly-once
//! under adversarial interleavings.
//!
//! Everything runs over loopback with ephemeral ports. CI runs this file
//! in its own `net-faults` job with `--test-threads=1` and a hard 120 s
//! timeout so a reintroduced hang fails fast instead of stalling the
//! workflow.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lazygp::bo::driver::{BoConfig, InitDesign};
use lazygp::coordinator::transport::{
    read_frame, run_worker_with, write_frame, LeaderMsg, ReconnectConfig, Transport, WorkerMsg,
    WorkerOptions, PROTOCOL_VERSION,
};
use lazygp::coordinator::{
    AsyncBo, AsyncCoordinatorConfig, RemoteEvalConfig, SocketPool, SocketPoolOptions, StudyId,
    Trial, TrialError, TrialOutcome, TrialPolicy,
};
use lazygp::objectives::Evaluation;
use lazygp::util::proptest as pt;
use lazygp::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// harness helpers
// ---------------------------------------------------------------------------

/// Leader options with heartbeats off — used by tests that manage fake
/// peers explicitly and must not race the reaper.
fn quiet_options() -> SocketPoolOptions {
    SocketPoolOptions {
        heartbeat_interval: Duration::ZERO,
        worker_loss_deadline: Duration::ZERO,
        ..Default::default()
    }
}

fn sphere_pool(options: SocketPoolOptions) -> SocketPool {
    SocketPool::listen_with(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed: 3,
            policy: TrialPolicy::default(),
        },
        options,
    )
    .expect("bind loopback")
}

fn trial(id: u64) -> Trial {
    trial_for(StudyId::SOLO, id)
}

fn trial_for(study: StudyId, id: u64) -> Trial {
    Trial { id, study, round: 0, x: vec![0.1, -0.2, 0.3, 0.0, -0.1], attempt: 0 }
}

/// Wait until `cond` holds or `timeout` passes; returns the elapsed time
/// on success.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> Option<Duration> {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return Some(t0.elapsed());
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    None
}

/// A hand-rolled worker the tests steer into adversarial behavior: it
/// speaks the real handshake, then reads/writes raw frames exactly when
/// told to (or goes silent, or vanishes).
struct FakeWorker {
    stream: TcpStream,
    worker_id: u64,
}

impl FakeWorker {
    fn connect(addr: SocketAddr, capacity: usize, resume: Option<u64>) -> FakeWorker {
        let mut stream = TcpStream::connect(addr).expect("connect fake worker");
        write_frame(
            &mut stream,
            &WorkerMsg::Hello { protocol: PROTOCOL_VERSION, capacity, resume }.to_json(),
        )
        .expect("send hello");
        let (welcome, _) = read_frame(&mut stream).expect("read welcome");
        let LeaderMsg::Welcome { worker_id, .. } = LeaderMsg::from_json(&welcome).unwrap() else {
            panic!("expected welcome");
        };
        FakeWorker { stream, worker_id }
    }

    /// Drop the link (simulated crash) and come back with a fresh
    /// connection advertising the previous id.
    fn reconnect(self, addr: SocketAddr) -> FakeWorker {
        let resume = Some(self.worker_id);
        drop(self.stream);
        FakeWorker::connect(addr, 2, resume)
    }

    /// Next dispatched trial, if any arrives within `timeout`.
    fn read_trial(&mut self, timeout: Duration) -> Option<Trial> {
        self.stream.set_read_timeout(Some(timeout)).unwrap();
        match read_frame(&mut self.stream) {
            Ok((json, _)) => match LeaderMsg::from_json(&json).ok()? {
                LeaderMsg::Dispatch(t) => Some(t),
                _ => None,
            },
            Err(_) => None,
        }
    }

    /// Report a (fabricated but well-formed) outcome for `t`. Errors are
    /// ignored — an adversarial worker does not care whether the leader
    /// still listens.
    fn send_outcome(&mut self, t: &Trial) {
        let outcome = TrialOutcome {
            trial: t.clone(),
            worker_id: 0,
            result: Ok(Evaluation { value: 1.0, sim_cost_s: 1.0 }),
            worker_seconds: 0.0,
            sim_cost_s: 1.0,
        };
        let _ = write_frame(&mut self.stream, &WorkerMsg::Outcome(outcome).to_json());
    }

    /// Report a typed failure for `t` (e.g. a worker-side deadline trip).
    fn send_error(&mut self, t: &Trial, err: TrialError) {
        let outcome = TrialOutcome {
            trial: t.clone(),
            worker_id: 0,
            result: Err(err),
            worker_seconds: 0.0,
            sim_cost_s: 0.05,
        };
        let _ = write_frame(&mut self.stream, &WorkerMsg::Outcome(outcome).to_json());
    }
}

// ---------------------------------------------------------------------------
// corrupted / truncated traffic
// ---------------------------------------------------------------------------

#[test]
fn garbage_length_prefix_is_rejected_and_link_reaped() {
    let pool = sphere_pool(quiet_options());
    let addr = pool.local_addr();
    let mut fake = FakeWorker::connect(addr, 1, None);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();

    // an adversarial 4 GiB length prefix: must be a counted protocol
    // rejection (no allocation, no hang), and the link must die
    fake.stream.write_all(&[0xff, 0xff, 0xff, 0xff]).unwrap();
    fake.stream.flush().unwrap();
    wait_until(Duration::from_secs(5), || pool.capacity_now() == 0)
        .expect("corrupt link must be reaped");
    let stats = pool.stats();
    assert_eq!(stats.faults.frames_rejected, 1, "{stats:?}");
    drop(fake);
    Box::new(pool).shutdown();
}

#[test]
fn mid_frame_disconnect_requeues_and_rescuer_completes_exactly_once() {
    let pool = sphere_pool(quiet_options());
    let addr = pool.local_addr();
    let mut fake = FakeWorker::connect(addr, 1, None);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();

    pool.dispatch(trial(7));
    let t = fake.read_trial(Duration::from_secs(10)).expect("dispatch arrives");
    assert_eq!(t.id, 7);
    // die mid-frame: promise 64 body bytes, deliver 10, vanish
    fake.stream.write_all(&64u32.to_be_bytes()).unwrap();
    fake.stream.write_all(&[b'{'; 10]).unwrap();
    fake.stream.flush().unwrap();
    drop(fake);

    wait_until(Duration::from_secs(5), || pool.stats().faults.requeued == 1)
        .expect("mid-frame disconnect must requeue the in-flight trial");

    // a healthy rescuer picks the trial up and completes it exactly once
    let addr_s = addr.to_string();
    let rescuer = std::thread::spawn(move || {
        run_worker_with(
            &addr_s,
            WorkerOptions { threads: 1, reconnect: ReconnectConfig::disabled(), ..Default::default() },
        )
        .expect("rescuer run")
    });
    let o = pool.poll_outcome(Duration::from_secs(20)).expect("rescued trial completes");
    assert_eq!(o.trial.id, 7);
    assert!(o.is_ok());
    assert!(pool.poll_outcome(Duration::from_millis(300)).is_none(), "no duplicate outcome");
    Box::new(pool).shutdown();
    assert_eq!(rescuer.join().unwrap().evaluated, 1);
}

// ---------------------------------------------------------------------------
// heartbeats: frozen peers
// ---------------------------------------------------------------------------

#[test]
fn frozen_worker_is_reaped_within_two_heartbeat_intervals() {
    // a SIGSTOP-style peer: completes the handshake, accepts a trial, then
    // never sends another byte while keeping the socket open — invisible
    // to TCP, reaped only by the application-level heartbeat deadline
    let interval = Duration::from_millis(150);
    let pool = sphere_pool(SocketPoolOptions {
        heartbeat_interval: interval,
        heartbeat_deadline: Duration::ZERO, // resolves to 2× interval
        worker_loss_deadline: Duration::ZERO,
        ..Default::default()
    });
    let addr = pool.local_addr();
    let mut frozen = FakeWorker::connect(addr, 1, None);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();
    let t0 = Instant::now();
    pool.dispatch(trial(0));
    assert_eq!(frozen.read_trial(Duration::from_secs(10)).expect("dispatch").id, 0);
    // ... and now: total silence.

    wait_until(Duration::from_secs(5), || pool.stats().faults.requeued == 1)
        .expect("frozen worker must be reaped and its trial rescued");
    // the mechanism bound is the deadline (2 × interval) from the reader's
    // last activity; generous slack keeps slow CI machines honest without
    // letting a keepalive-scale regression (minutes) through
    assert!(
        t0.elapsed() <= 2 * interval + Duration::from_secs(2),
        "reap took {:?}, expected ≈ {:?}",
        t0.elapsed(),
        2 * interval
    );
    let stats = pool.stats();
    assert!(stats.faults.heartbeats_missed >= 1, "{stats:?}");
    assert_eq!(stats.faults.requeued, 1, "trial requeued exactly once: {stats:?}");
    assert_eq!(pool.capacity_now(), 0);

    // a healthy worker joins (pinging on the negotiated cadence) and picks
    // the rescued trial up; the frozen socket never produces a duplicate
    let addr_s = addr.to_string();
    let healthy = std::thread::spawn(move || {
        run_worker_with(
            &addr_s,
            WorkerOptions { threads: 1, reconnect: ReconnectConfig::disabled(), ..Default::default() },
        )
        .expect("healthy worker")
    });
    let o = pool.poll_outcome(Duration::from_secs(20)).expect("rescued trial completes");
    assert_eq!(o.trial.id, 0);
    assert!(pool.poll_outcome(Duration::from_millis(300)).is_none(), "no duplicate outcome");
    drop(frozen);
    Box::new(pool).shutdown();
    assert_eq!(healthy.join().unwrap().evaluated, 1);
}

// ---------------------------------------------------------------------------
// leader crash + restart, worker reconnect
// ---------------------------------------------------------------------------

#[test]
fn leader_restart_worker_reconnects_and_completes() {
    let pool1 = sphere_pool(quiet_options());
    let addr = pool1.local_addr();
    let addr_s = addr.to_string();
    let worker = std::thread::spawn(move || {
        run_worker_with(
            &addr_s,
            WorkerOptions {
                threads: 1,
                reconnect: ReconnectConfig {
                    max_attempts: 40,
                    base_backoff: Duration::from_millis(25),
                    max_backoff: Duration::from_millis(250),
                    jitter_seed: 7,
                },
                ..Default::default()
            },
        )
        .expect("worker survives the restart")
    });
    pool1.wait_for_capacity(1, Duration::from_secs(10)).unwrap();
    pool1.dispatch(trial(0));
    let o = pool1.poll_outcome(Duration::from_secs(20)).expect("first trial completes");
    assert_eq!(o.trial.id, 0);

    // crash the leader: no Shutdown frames, sockets torn down abruptly
    pool1.abort();

    // restart on the *same* port (std's TcpListener sets SO_REUSEADDR on
    // unix; a transient EADDRINUSE from lingering state is retried)
    let deadline = Instant::now() + Duration::from_secs(10);
    let pool2 = loop {
        match SocketPool::listen_with(
            &addr.to_string(),
            RemoteEvalConfig {
                objective: "sphere5".into(),
                sleep_scale: 0.0,
                fail_prob: 0.0,
                seed: 3,
                policy: TrialPolicy::default(),
            },
            quiet_options(),
        ) {
            Ok(p) => break p,
            Err(e) => {
                assert!(Instant::now() < deadline, "could not rebind {addr}: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    // the worker's backoff loop finds the restarted leader and re-handshakes
    pool2.wait_for_capacity(1, Duration::from_secs(20)).unwrap();
    assert_eq!(pool2.stats().faults.reconnects, 1, "hello must carry the resume id");

    pool2.dispatch(trial(1));
    let o = pool2.poll_outcome(Duration::from_secs(20)).expect("post-restart trial completes");
    assert_eq!(o.trial.id, 1);
    Box::new(pool2).shutdown(); // graceful: the worker exits cleanly

    let summary = worker.join().unwrap();
    assert_eq!(summary.evaluated, 2, "one trial per leader incarnation");
    assert_eq!(summary.reconnects, 1);
}

// ---------------------------------------------------------------------------
// crossed outcome/requeue races: the delivery gate
// ---------------------------------------------------------------------------

#[test]
fn stale_outcome_after_reconnect_is_deduped() {
    let pool = sphere_pool(quiet_options());
    let addr = pool.local_addr();
    let mut fake = FakeWorker::connect(addr, 1, None);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();
    pool.dispatch(trial(7));
    let t = fake.read_trial(Duration::from_secs(10)).expect("dispatch");

    // crash without reporting: the leader requeues trial 7
    let resume = Some(fake.worker_id);
    drop(fake);
    wait_until(Duration::from_secs(5), || pool.stats().faults.requeued == 1).expect("requeue");

    // a healthy worker completes the rescued trial first
    let addr_s = addr.to_string();
    let healthy = std::thread::spawn(move || {
        run_worker_with(
            &addr_s,
            WorkerOptions { threads: 1, reconnect: ReconnectConfig::disabled(), ..Default::default() },
        )
        .expect("healthy worker")
    });
    let o = pool.poll_outcome(Duration::from_secs(20)).expect("rescued trial completes");
    assert_eq!(o.trial.id, 7);

    // now the crashed worker comes back and re-delivers its stale result:
    // the delivery gate must drop it — the coordinator already saw id 7
    let mut returned = FakeWorker::connect(addr, 1, resume);
    returned.send_outcome(&t);
    wait_until(Duration::from_secs(5), || pool.stats().faults.duplicates_dropped == 1)
        .expect("stale outcome must be counted as a dropped duplicate");
    assert!(pool.poll_outcome(Duration::from_millis(300)).is_none(), "no duplicate delivery");
    let stats = pool.stats();
    assert_eq!(stats.faults.reconnects, 1, "{stats:?}");

    drop(returned);
    Box::new(pool).shutdown();
    healthy.join().unwrap();
}

#[test]
fn redelivered_outcome_cancels_pending_requeue() {
    // inverse order of the test above: the worker reconnects and
    // re-delivers *before* (or while) the leader re-dispatches the rescued
    // trial — either interleaving must deliver id 3 exactly once
    let pool = sphere_pool(quiet_options());
    let addr = pool.local_addr();
    let mut fake = FakeWorker::connect(addr, 1, None);
    pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap();
    pool.dispatch(trial(3));
    let t = fake.read_trial(Duration::from_secs(10)).expect("dispatch");

    let mut returned = fake.reconnect(addr); // crash + immediate return
    wait_until(Duration::from_secs(5), || pool.stats().faults.requeued == 1).expect("requeue");
    returned.send_outcome(&t); // buffered re-delivery

    let o = pool.poll_outcome(Duration::from_secs(10)).expect("re-delivered outcome arrives");
    assert_eq!(o.trial.id, 3);
    // the requeued copy must not produce a second delivery, whether it was
    // still queued (cancelled) or already re-dispatched (deduped); serve
    // any re-dispatch the leader may have raced out
    if let Some(redispatched) = returned.read_trial(Duration::from_millis(300)) {
        returned.send_outcome(&redispatched);
    }
    assert!(pool.poll_outcome(Duration::from_millis(500)).is_none(), "exactly-once violated");
    drop(returned);
    Box::new(pool).shutdown();
}

// ---------------------------------------------------------------------------
// capacity accounting + total worker loss
// ---------------------------------------------------------------------------

#[test]
fn wait_for_capacity_is_not_fooled_by_instant_dropper() {
    let pool = sphere_pool(quiet_options());
    let addr = pool.local_addr();
    // the wait runs concurrently with a worker that completes the
    // handshake and instantly vanishes: the brief alive window must not
    // satisfy the wait (the confirmation grace re-checks after admission)
    let dropper = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        let d = FakeWorker::connect(addr, 1, None);
        drop(d);
    });
    let res = pool.wait_for_capacity(1, Duration::from_millis(600));
    assert!(res.is_err(), "an instant-dropper must not satisfy the capacity wait");
    dropper.join().unwrap();

    // a real worker does
    let addr_s = addr.to_string();
    let worker = std::thread::spawn(move || {
        run_worker_with(
            &addr_s,
            WorkerOptions { threads: 1, reconnect: ReconnectConfig::disabled(), ..Default::default() },
        )
        .expect("worker")
    });
    assert_eq!(pool.wait_for_capacity(1, Duration::from_secs(10)).unwrap(), 1);
    Box::new(pool).shutdown();
    worker.join().unwrap();
}

#[test]
fn recv_surfaces_all_workers_lost_instead_of_wedging() {
    let pool = sphere_pool(SocketPoolOptions {
        heartbeat_interval: Duration::ZERO,
        worker_loss_deadline: Duration::from_millis(300),
        ..Default::default()
    });
    pool.dispatch(trial(0)); // queued work, nobody to run it
    let t0 = Instant::now();
    let err = pool.recv().expect_err("recv must give up, not wedge");
    assert!(err.is_all_workers_lost(), "got: {err}");
    assert!(err.to_string().contains("0.3s"), "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "gave up after {:?}, deadline was 300ms",
        t0.elapsed()
    );
    Box::new(pool).shutdown();
}

// ---------------------------------------------------------------------------
// end-to-end: AsyncBo over a churning transport
// ---------------------------------------------------------------------------

#[test]
fn async_bo_survives_worker_churn_exactly_once() {
    // two honest workers + one that takes a trial and crashes mid-run: the
    // coordinator must end with exactly the budgeted observations — the
    // crashed trial requeued (once) and no duplicate id ever observed
    let pool = SocketPool::listen_with(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "levy2".into(),
            sleep_scale: 1e-4,
            fail_prob: 0.0,
            seed: 9,
            policy: TrialPolicy::default(),
        },
        SocketPoolOptions {
            // heartbeats off: the silent saboteur must live long enough to
            // grab a trial (frozen-peer reaping has its own test above)
            heartbeat_interval: Duration::ZERO,
            worker_loss_deadline: Duration::from_secs(30),
            checksum: true, // exercise checksummed frames end-to-end
            ..Default::default()
        },
    )
    .unwrap();
    let addr = pool.local_addr();
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let addr_s = addr.to_string();
            std::thread::spawn(move || {
                run_worker_with(
                    &addr_s,
                    WorkerOptions {
                        threads: 1,
                        reconnect: ReconnectConfig { jitter_seed: i, ..Default::default() },
                        ..WorkerOptions::default()
                    },
                )
                .expect("honest worker")
            })
        })
        .collect();
    // the saboteur advertises a slot, grabs one trial, dies
    let saboteur = std::thread::spawn(move || {
        let mut fake = FakeWorker::connect(addr, 1, None);
        let _ = fake.read_trial(Duration::from_secs(30));
        // drop: the leader requeues whatever was in flight here
    });
    pool.wait_for_capacity(3, Duration::from_secs(10)).unwrap();

    let bo = BoConfig::lazy().with_seed(23).with_init(InitDesign::Lhs(4));
    let obj: Arc<dyn lazygp::objectives::Objective> =
        Arc::from(lazygp::objectives::by_name("levy2").unwrap());
    let mut abo = AsyncBo::with_transport(
        bo,
        obj,
        Box::new(pool),
        AsyncCoordinatorConfig::default(),
    );
    let best = abo.run_until_evals(16).expect("churn must not starve the run");
    assert!(best.value.is_finite());
    assert_eq!(abo.driver().history().len(), 16, "exactly the budget, despite churn");
    assert_eq!(abo.driver().surrogate().len(), 16);
    assert_eq!(abo.driver().fantasies_active(), 0);
    let s = abo.stats();
    assert_eq!(s.fantasies_issued, s.fantasy_rollbacks);
    let stats = abo.transport_stats();
    assert!(stats.faults.requeued >= 1, "the saboteur's trial was rescued: {stats:?}");
    abo.finish();
    saboteur.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
}

// ---------------------------------------------------------------------------
// property: exactly-once delivery under adversarial interleavings
// ---------------------------------------------------------------------------

/// One adversarial episode: N trials against a single fake worker that,
/// per dispatch, randomly completes, double-reports, vanishes mid-trial,
/// or reports-then-vanishes-then-re-reports. The coordinator-facing
/// outcome stream must contain every trial id exactly once.
fn adversarial_episode(seed: u64) -> bool {
    const N: usize = 5;
    let mut rng = Pcg64::new(seed);
    let pool = sphere_pool(quiet_options());
    let addr = pool.local_addr();
    for id in 0..N as u64 {
        pool.dispatch(trial(id));
    }
    let mut fake = FakeWorker::connect(addr, 2, None);
    let mut received: Vec<u64> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    while received.len() < N && Instant::now() < deadline {
        while let Some(o) = pool.poll_outcome(Duration::from_millis(1)) {
            received.push(o.trial.id);
        }
        let Some(t) = fake.read_trial(Duration::from_millis(50)) else { continue };
        match rng.below(4) {
            0 => fake.send_outcome(&t),
            1 => {
                // double-report the same id on one link
                fake.send_outcome(&t);
                fake.send_outcome(&t);
            }
            2 => {
                // vanish mid-trial; the leader requeues, we come back
                fake = fake.reconnect(addr);
            }
            _ => {
                // report, vanish, come back, stale-re-report
                fake.send_outcome(&t);
                let stale = t.clone();
                fake = fake.reconnect(addr);
                fake.send_outcome(&stale);
            }
        }
    }
    while received.len() < N {
        match pool.poll_outcome(Duration::from_millis(200)) {
            Some(o) => received.push(o.trial.id),
            None => break,
        }
    }
    drop(fake);
    Box::new(pool).shutdown();
    let mut unique = received.clone();
    unique.sort_unstable();
    unique.dedup();
    received.len() == N && unique.len() == N
}

#[test]
fn prop_outcome_trial_ids_unique_under_adversarial_requeue_interleavings() {
    let seeds = pt::usize_in(0, 1_000_000);
    pt::check("outcome_ids_exactly_once", &seeds, |&seed| adversarial_episode(seed as u64));
}

/// One evaluation-fault episode: the pool's study policy carries a 50 ms
/// per-attempt deadline, and the fake worker, per dispatch, randomly
/// completes, reports a worker-side `Timeout`, hangs past the 2× reap
/// window and then files the late stale outcome for the attempt the
/// leader already cancelled, double-reports, vanishes mid-trial, or
/// reports-then-vanishes-then-re-reports. The coordinator-facing stream
/// must still contain every trial id exactly once (ok or err).
fn fault_adversarial_episode(seed: u64) -> bool {
    const N: usize = 4;
    let mut rng = Pcg64::new(seed);
    let pool = SocketPool::listen_with(
        "127.0.0.1:0",
        RemoteEvalConfig {
            objective: "sphere5".into(),
            sleep_scale: 0.0,
            fail_prob: 0.0,
            seed: 3,
            policy: TrialPolicy { deadline_s: 0.05, ..TrialPolicy::default() },
        },
        quiet_options(),
    )
    .expect("bind loopback");
    let addr = pool.local_addr();
    for id in 0..N as u64 {
        pool.dispatch(trial(id));
    }
    let mut fake = FakeWorker::connect(addr, 2, None);
    let mut received: Vec<u64> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    while received.len() < N && Instant::now() < deadline {
        while let Some(o) = pool.poll_outcome(Duration::from_millis(1)) {
            received.push(o.trial.id);
        }
        let Some(t) = fake.read_trial(Duration::from_millis(50)) else { continue };
        match rng.below(6) {
            0 => fake.send_outcome(&t),
            1 => fake.send_error(&t, TrialError::Timeout(0.05)),
            2 => {
                // hang past the 2× reap window; the leader cancels and
                // requeues, then this late stale outcome must lose (or
                // win — either way exactly one delivery) at the gate
                std::thread::sleep(Duration::from_millis(150));
                fake.send_outcome(&t);
            }
            3 => {
                fake.send_outcome(&t);
                fake.send_outcome(&t); // duplicate on one link
            }
            4 => fake = fake.reconnect(addr), // vanish mid-trial
            _ => {
                fake.send_outcome(&t);
                let stale = t.clone();
                fake = fake.reconnect(addr);
                fake.send_outcome(&stale); // stale re-report after requeue
            }
        }
    }
    while received.len() < N {
        match pool.poll_outcome(Duration::from_millis(200)) {
            Some(o) => received.push(o.trial.id),
            None => break,
        }
    }
    drop(fake);
    Box::new(pool).shutdown();
    let mut unique = received.clone();
    unique.sort_unstable();
    unique.dedup();
    received.len() == N && unique.len() == N
}

#[test]
fn prop_exactly_once_survives_timeouts_cancels_and_late_outcomes() {
    let seeds = pt::usize_in(0, 1_000_000);
    pt::check("fault_ids_exactly_once", &seeds, |&seed| {
        fault_adversarial_episode(seed as u64)
    });
}

/// Two studies share one fleet and deliberately reuse the same bare trial
/// ids; the delivery gate is keyed by `(study, trial)`, so under the same
/// adversarial worker behaviors every *pair* must reach the coordinator
/// exactly once, and the per-study counters must reconcile.
fn two_study_adversarial_episode(seed: u64) -> bool {
    const N: u64 = 4;
    let mut rng = Pcg64::new(seed);
    let pool = sphere_pool(quiet_options());
    let a = StudyId(1);
    let b = StudyId(2);
    for (study, objective) in [(a, "sphere5"), (b, "levy2")] {
        pool.register_study(
            study,
            RemoteEvalConfig {
                objective: objective.into(),
                sleep_scale: 0.0,
                fail_prob: 0.0,
                seed,
                policy: TrialPolicy::default(),
            },
        )
        .expect("register study");
    }
    for id in 0..N {
        // identical bare ids on purpose: only (study, id) is unique
        pool.dispatch(trial_for(a, id));
        pool.dispatch(trial_for(b, id));
    }
    let mut fake = FakeWorker::connect(pool.local_addr(), 2, None);
    let addr = pool.local_addr();
    let total = (2 * N) as usize;
    let mut received: Vec<(u64, u64)> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    while received.len() < total && Instant::now() < deadline {
        while let Some(o) = pool.poll_outcome(Duration::from_millis(1)) {
            received.push((o.trial.study.0, o.trial.id));
        }
        let Some(t) = fake.read_trial(Duration::from_millis(50)) else { continue };
        match rng.below(4) {
            0 => fake.send_outcome(&t),
            1 => {
                fake.send_outcome(&t);
                fake.send_outcome(&t); // duplicate on one link
            }
            2 => fake = fake.reconnect(addr), // vanish mid-trial
            _ => {
                fake.send_outcome(&t);
                let stale = t.clone();
                fake = fake.reconnect(addr);
                fake.send_outcome(&stale); // stale re-report after requeue
            }
        }
    }
    while received.len() < total {
        match pool.poll_outcome(Duration::from_millis(200)) {
            Some(o) => received.push((o.trial.study.0, o.trial.id)),
            None => break,
        }
    }
    drop(fake);
    let stats = pool.stats();
    Box::new(pool).shutdown();
    let mut unique = received.clone();
    unique.sort_unstable();
    unique.dedup();
    let per_study_reconciled = [a, b].iter().all(|id| {
        stats
            .studies
            .iter()
            .find(|r| r.study == id.0)
            .is_some_and(|r| r.completed == N)
    });
    received.len() == total && unique.len() == total && per_study_reconciled
}

#[test]
fn prop_two_studies_sharing_a_fleet_deliver_exactly_once_per_study() {
    let seeds = pt::usize_in(0, 1_000_000);
    pt::check("two_study_ids_exactly_once", &seeds, |&seed| {
        two_study_adversarial_episode(seed as u64)
    });
}
