//! Surrogate conformance suite: every backend behind [`Surrogate`] must
//! honor the same contracts — checkpoint/rollback restores the posterior
//! *bitwise*, truncate lands on the exact posterior of a fresh prefix run,
//! fantasies round-trip through `retract_fantasies`, and `predict_batch`
//! agrees with sequential `predict` to the bit. The suite runs the same
//! assertions over LazyGp, ExactGp and DngoSurrogate (no-refit configs, so
//! the hyper-parameters stay frozen and the bitwise contracts are exact),
//! plus a smoke pass over every [`SurrogateSpec`]-built backend.

use lazygp::gp::exact::{ExactGp, ExactGpConfig};
use lazygp::gp::lazy::{LazyGp, LazyGpConfig};
use lazygp::gp::linear::{DngoConfig, DngoSurrogate};
use lazygp::gp::{Surrogate, SurrogateSpec};
use lazygp::kernels::Kernel;
use lazygp::util::parallel::Parallelism;
use lazygp::util::rng::Pcg64;

const DIM: usize = 2;

/// The three backends under no-refit configs: frozen hyper-parameters are
/// what make the bitwise checkpoint/truncate contracts testable.
fn backends() -> Vec<(&'static str, Box<dyn Surrogate>)> {
    vec![
        ("lazy", Box::new(LazyGp::new(LazyGpConfig::default())) as Box<dyn Surrogate>),
        (
            "exact",
            Box::new(ExactGp::new(ExactGpConfig { refit_each_step: false, ..Default::default() })),
        ),
        ("dngo", Box::new(DngoSurrogate::new(DngoConfig { rff_dim: 64, ..Default::default() }))),
    ]
}

fn point(rng: &mut Pcg64) -> Vec<f64> {
    (0..DIM).map(|_| rng.uniform(-3.0, 3.0)).collect()
}

fn objective(x: &[f64]) -> f64 {
    -(x[0] * x[0] + 0.5 * x[1] * x[1]) + (x[0] * 2.0).sin()
}

fn feed(s: &mut dyn Surrogate, rng: &mut Pcg64, n: usize) -> Vec<(Vec<f64>, f64)> {
    let mut fed = Vec::with_capacity(n);
    for _ in 0..n {
        let x = point(rng);
        let y = objective(&x);
        s.observe(&x, y);
        fed.push((x, y));
    }
    fed
}

fn probes() -> Vec<Vec<f64>> {
    let mut rng = Pcg64::new(0xbeef);
    (0..7).map(|_| point(&mut rng)).collect()
}

/// Bitwise fingerprint of the posterior at the probe grid.
fn posterior_bits(s: &dyn Surrogate, probes: &[Vec<f64>]) -> Vec<(u64, u64)> {
    probes.iter().map(|p| s.predict(p)).map(|(m, v)| (m.to_bits(), v.to_bits())).collect()
}

#[test]
fn checkpoint_rollback_restores_posterior_bitwise() {
    let probes = probes();
    for (name, mut s) in backends() {
        let mut rng = Pcg64::new(11);
        feed(s.as_mut(), &mut rng, 20);
        let before_bits = posterior_bits(s.as_ref(), &probes);
        let before_digest = s.state_digest();
        let before_len = s.len();

        s.checkpoint();
        let batch: Vec<(Vec<f64>, f64)> =
            (0..4).map(|_| (point(&mut rng), -1.0)).collect();
        s.observe_fantasies(&batch);
        assert_eq!(s.fantasies_active(), 4, "{name}");
        assert_ne!(
            posterior_bits(s.as_ref(), &probes),
            before_bits,
            "{name}: fantasies must actually move the posterior"
        );

        assert_eq!(s.rollback(), 4, "{name}");
        assert_eq!(s.fantasies_active(), 0, "{name}");
        assert_eq!(s.len(), before_len, "{name}");
        assert_eq!(posterior_bits(s.as_ref(), &probes), before_bits, "{name}");
        assert_eq!(s.state_digest(), before_digest, "{name}");
        // the window is closed: a second rollback is a no-op
        assert_eq!(s.rollback(), 0, "{name}");
    }
}

#[test]
fn fantasies_roundtrip_through_retract() {
    let probes = probes();
    for (name, mut s) in backends() {
        let mut rng = Pcg64::new(13);
        feed(s.as_mut(), &mut rng, 15);
        let before_bits = posterior_bits(s.as_ref(), &probes);
        let incumbent_bits = s.incumbent().map(|(_, y)| y.to_bits());

        // observe_fantasy opens the window implicitly — no explicit
        // checkpoint call
        for _ in 0..3 {
            s.observe_fantasy(&point(&mut rng), 100.0);
        }
        assert_eq!(s.fantasies_active(), 3, "{name}");
        assert_eq!(s.retract_fantasies(), 3, "{name}");
        assert_eq!(s.fantasies_active(), 0, "{name}");
        assert_eq!(posterior_bits(s.as_ref(), &probes), before_bits, "{name}");
        // the +100.0 fantasy incumbent must not leak past retraction
        assert_eq!(s.incumbent().map(|(_, y)| y.to_bits()), incumbent_bits, "{name}");
    }
}

#[test]
fn truncate_matches_fresh_prefix_bitwise() {
    let probes = probes();
    for ((name, mut full), (_, mut fresh)) in backends().into_iter().zip(backends()) {
        let mut rng = Pcg64::new(17);
        let fed = feed(full.as_mut(), &mut rng, 24);
        full.truncate(10);
        assert_eq!(full.len(), 10, "{name}");

        for (x, y) in fed.iter().take(10) {
            fresh.observe(x, *y);
        }
        assert_eq!(
            posterior_bits(full.as_ref(), &probes),
            posterior_bits(fresh.as_ref(), &probes),
            "{name}: truncated posterior must be bitwise the fresh-prefix posterior"
        );
        assert_eq!(full.state_digest(), fresh.state_digest(), "{name}");
        let (fx, fy) = full.incumbent().expect("incumbent after truncate");
        let (gx, gy) = fresh.incumbent().expect("incumbent fresh");
        assert_eq!(fy.to_bits(), gy.to_bits(), "{name}");
        assert_eq!(fx, gx, "{name}");
    }
}

#[test]
fn truncate_to_zero_resets_to_prior() {
    for (name, mut s) in backends() {
        let mut rng = Pcg64::new(19);
        feed(s.as_mut(), &mut rng, 8);
        s.truncate(0);
        assert_eq!(s.len(), 0, "{name}");
        assert!(s.is_empty(), "{name}");
        assert!(s.incumbent().is_none(), "{name}");
        let (m, v) = s.predict(&[0.3, -0.4]);
        assert_eq!(m, 0.0, "{name}: empty model predicts the prior mean");
        assert!(v > 0.0, "{name}: empty model predicts the prior variance");
        // the model remains usable after a full reset
        feed(s.as_mut(), &mut rng, 5);
        assert_eq!(s.len(), 5, "{name}");
        assert!(s.predict(&[0.0, 0.0]).0.is_finite(), "{name}");
    }
}

#[test]
fn predict_batch_matches_sequential_bitwise() {
    for (name, mut s) in backends() {
        let mut rng = Pcg64::new(23);
        feed(s.as_mut(), &mut rng, 18);
        let cands: Vec<Vec<f64>> = (0..33).map(|_| point(&mut rng)).collect();
        let batched = s.predict_batch(&cands);
        assert_eq!(batched.len(), cands.len(), "{name}");
        for (c, &(bm, bv)) in cands.iter().zip(&batched) {
            let (m, v) = s.predict(c);
            assert_eq!(m.to_bits(), bm.to_bits(), "{name}: batched mean diverged");
            assert_eq!(v.to_bits(), bv.to_bits(), "{name}: batched variance diverged");
        }
    }
}

#[test]
fn spec_built_backends_are_usable() {
    let specs = [
        (SurrogateSpec::Lazy { lag: 2 }, "lazy"),
        (SurrogateSpec::Exact, "exact"),
        (SurrogateSpec::Dngo { rff_dim: 32 }, "dngo"),
    ];
    for (spec, want_name) in specs {
        let mut s = spec.build(Kernel::paper_default(), 5, Parallelism::Serial, 42);
        assert_eq!(s.name(), want_name);
        assert!(s.is_empty());
        let mut rng = Pcg64::new(29);
        feed(s.as_mut(), &mut rng, 8);
        assert_eq!(s.len(), 8);
        assert!(s.mem_bytes_est() > 0, "{want_name}");
        let (m, v) = s.predict(&[0.1, 0.2]);
        assert!(m.is_finite() && v.is_finite() && v >= 0.0, "{want_name}");
        assert!(s.log_marginal_likelihood().is_finite(), "{want_name}");
        assert!(s.fit(), "{want_name}: fit on a populated model must apply");
        assert!(s.predict(&[0.1, 0.2]).0.is_finite(), "{want_name}");
    }
}

#[test]
fn update_seconds_accumulates_everywhere() {
    for (name, mut s) in backends() {
        let mut rng = Pcg64::new(31);
        feed(s.as_mut(), &mut rng, 10);
        assert!(s.update_seconds() >= 0.0, "{name}");
        // async pressure is at minimum accepted by every backend
        s.note_async_pressure(3);
        s.note_async_pressure(0);
    }
}
