"""Layer-2 JAX model: batched GP posterior scoring (paper Alg. 1 lines
4–6 + Eq. 11), calling the Layer-1 Pallas kernels.

``gp_score`` is the compute hot-spot the Rust coordinator offloads: given a
Cholesky factor ``L`` (maintained incrementally on the Rust side via the
paper's Alg. 3), the weights ``α``, and a batch of ``M`` candidate points,
produce posterior mean, variance and Expected Improvement per candidate.

Static shapes only (AOT): the Rust runtime pads the live GP state into the
nearest size bucket:

* ``x_train`` padded rows — arbitrary values, killed by ``mask``;
* ``l_factor`` padded rows — zeros with a unit diagonal, so the triangular
  solve leaves padded coordinates at 0;
* ``alpha`` padded entries — zeros.

With that padding, the padded subspace contributes exactly nothing to
either the mean or the variance, which the pytest suite asserts.
"""

import jax
import jax.numpy as jnp

from .kernels.ei import expected_improvement
from .kernels.matern import matern52_cross


def solve_lower_loop(l, b):
    """Forward substitution ``L X = B`` (``L`` lower-triangular ``[N,N]``,
    ``B`` ``[N,M]``) as a ``fori_loop`` of masked row updates.

    Deliberately NOT ``jax.scipy.linalg.solve_triangular``: on CPU that
    lowers to a ``lapack_strsm_ffi`` custom-call (API_VERSION_TYPED_FFI)
    which the ``xla`` crate's bundled xla_extension 0.5.1 cannot compile.
    This loop lowers to ``while`` + ``dynamic-(update-)slice`` — opcodes
    every XLA version supports — at the same O(N²M) flop count.
    """
    n = l.shape[0]
    row_idx = jnp.arange(n)

    def body(i, x):
        li = jax.lax.dynamic_slice_in_dim(l, i, 1, axis=0)          # [1, N]
        # only already-solved rows (j < i) contribute
        solved = jnp.where((row_idx < i)[:, None], x, 0.0)          # [N, M]
        s = li @ solved                                             # [1, M]
        bi = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)          # [1, M]
        lii = jax.lax.dynamic_slice(l, (i, i), (1, 1))              # [1, 1]
        xi = (bi - s) / lii
        return jax.lax.dynamic_update_slice_in_dim(x, xi, i, axis=0)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def gp_score(x_train, l_factor, alpha, mask, cand, best_f, xi, mean_offset,
             *, variance=1.0, length_scale=1.0):
    """Posterior + EI for a candidate batch.

    Args:
      x_train: ``[N, D]`` training inputs (padded to the bucket size N).
      l_factor: ``[N, N]`` lower Cholesky factor of ``K_y`` (padded).
      alpha: ``[N]`` weights ``K_y⁻¹ (y − μ₀)`` (padded with zeros).
      mask: ``[N]`` 1.0 for live rows, 0.0 for padding.
      cand: ``[M, D]`` candidate points.
      best_f, xi, mean_offset: scalars (incumbent, EI trade-off, prior mean).
      variance, length_scale: kernel hyper-parameters, baked at trace time —
        the lazy GP freezes them (paper §3.3), which is precisely what makes
        AOT compilation of this graph sound.

    Returns:
      ``(mu[M], var[M], ei[M])``.
    """
    # L1 kernel: K*ᵀ ∈ [M, N] cross-covariance on the MXU-friendly path
    kstar = matern52_cross(cand, x_train, variance=variance, length_scale=length_scale)
    kstar = kstar * mask[None, :]
    # Alg. 1 line 4: mean
    mu = kstar @ alpha + mean_offset
    # Alg. 1 lines 5–6: v = L⁻¹ k*, var = κ(x*,x*) − vᵀv
    v = solve_lower_loop(l_factor, kstar.T)
    var = jnp.maximum(variance - jnp.sum(v * v, axis=0), 0.0)
    # L1 kernel: fused EI tail
    ei = expected_improvement(mu, var, best_f, xi)
    return mu, var, ei
