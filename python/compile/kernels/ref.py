"""Pure-jnp reference oracles for the Pallas kernels and the L2 model.

These are the single source of correctness for the build-time stack:
pytest asserts the Pallas kernels (`matern.py`, `ei.py`) and the lowered
model (`model.py`) against these functions, and the Rust runtime's parity
tests compare the compiled artifact output against the same math
implemented natively in f64.
"""

import jax
import jax.numpy as jnp

SQRT5 = 5.0 ** 0.5
INV_SQRT2 = 2.0 ** -0.5
INV_SQRT_2PI = float(1.0 / (2.0 * jnp.pi) ** 0.5)


def matern52_cross_ref(cand, x_train, variance=1.0, length_scale=1.0):
    """Cross-covariance ``K*ᵀ ∈ R^{M×N}`` under Matérn-5/2.

    The paper's Eq. 3 with the sign of the exponent corrected (see
    DESIGN.md §5): ``σ² (1 + √5 d/ρ + 5d²/(3ρ²)) exp(−√5 d/ρ)``.
    """
    # pairwise squared distances, numerically clamped at 0
    d2 = jnp.sum((cand[:, None, :] - x_train[None, :, :]) ** 2, axis=-1)
    d2 = jnp.maximum(d2, 0.0)
    d = jnp.sqrt(d2) / length_scale
    a = SQRT5 * d
    return variance * (1.0 + a + (5.0 / 3.0) * d * d) * jnp.exp(-a)


def norm_cdf_ref(z):
    return 0.5 * (1.0 + jax.lax.erf(z * INV_SQRT2))


def norm_pdf_ref(z):
    return jnp.exp(-0.5 * z * z) * INV_SQRT_2PI


def ei_ref(mu, var, best_f, xi):
    """Expected Improvement (paper Eq. 11, Jones/Mockus form).

    ``γ = μ − f' − ξ``, ``Z = γ/σ``; ``EI = γΦ(Z) + σφ(Z)`` for σ > 0,
    0 where σ vanishes.
    """
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    gamma = mu - best_f - xi
    safe_sigma = jnp.where(sigma > 1e-12, sigma, 1.0)
    z = gamma / safe_sigma
    ei = gamma * norm_cdf_ref(z) + safe_sigma * norm_pdf_ref(z)
    return jnp.where(sigma > 1e-12, jnp.maximum(ei, 0.0), 0.0)


def gp_score_ref(x_train, l_factor, alpha, mask, cand, best_f, xi,
                 mean_offset, variance=1.0, length_scale=1.0):
    """Posterior mean/variance + EI for a candidate batch (paper Alg. 1).

    ``mask`` zeroes the covariance contributions of padded training rows;
    the Rust runtime pads ``l_factor`` with unit diagonal rows and ``alpha``
    with zeros so the padded subspace is inert.
    """
    kstar = matern52_cross_ref(cand, x_train, variance, length_scale)
    kstar = kstar * mask[None, :]
    mu = kstar @ alpha + mean_offset
    v = jax.scipy.linalg.solve_triangular(l_factor, kstar.T, lower=True)
    var = jnp.maximum(variance - jnp.sum(v * v, axis=0), 0.0)
    ei = ei_ref(mu, var, best_f, xi)
    return mu, var, ei
