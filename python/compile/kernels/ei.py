"""Layer-1 Pallas kernel: fused Expected Improvement.

Elementwise over the candidate batch: given posterior mean/variance and the
incumbent, produce EI (paper Eq. 11, Jones/Mockus form). Pure VPU work —
one fused multiply/exp/erf chain per lane, no memory traffic beyond the
three M-vectors, so the kernel exists to keep the whole scoring pipeline
inside one lowered module rather than for FLOP throughput.

``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INV_SQRT2 = 2.0 ** -0.5
INV_SQRT_2PI = float(1.0 / (2.0 * jnp.pi) ** 0.5)

# Lane-block size for the 1-D grid.
BLOCK = 128


def _erf_approx(x):
    """Abramowitz–Stegun 7.1.26 rational erf, |err| < 1.5e-7 — well below
    f32 resolution for the EI decision.

    Deliberately NOT ``jax.lax.erf``: modern StableHLO→HLO conversion emits
    a first-class ``erf`` opcode that the ``xla`` crate's bundled
    xla_extension 0.5.1 text parser rejects ("Unknown opcode: erf"); this
    expansion lowers to mul/add/exp which every XLA version parses.
    """
    a1, a2, a3 = 0.254829592, -0.284496736, 1.421413741
    a4, a5, p = -1.453152027, 1.061405429, 0.3275911
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    poly = ((((a5 * t + a4) * t + a3) * t + a2) * t + a1) * t
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _ei_block_kernel(mu_ref, var_ref, best_ref, xi_ref, out_ref):
    mu = mu_ref[...]
    var = var_ref[...]
    best_f = best_ref[0]
    xi = xi_ref[0]
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    gamma = mu - best_f - xi
    safe = jnp.where(sigma > 1e-12, sigma, 1.0)
    z = gamma / safe
    cdf = 0.5 * (1.0 + _erf_approx(z * INV_SQRT2))
    pdf = jnp.exp(-0.5 * z * z) * INV_SQRT_2PI
    ei = gamma * cdf + safe * pdf
    out_ref[...] = jnp.where(sigma > 1e-12, jnp.maximum(ei, 0.0), 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def expected_improvement(mu, var, best_f, xi, block=BLOCK):
    """EI over a candidate batch. ``mu``/``var`` are ``[M]``; ``best_f`` and
    ``xi`` are scalars (passed as rank-1 size-1 arrays to sit in SMEM-like
    operands)."""
    (m,) = mu.shape
    block = min(block, m)
    assert m % block == 0, f"M={m} not a multiple of block={block}"
    best_arr = jnp.reshape(jnp.asarray(best_f, dtype=mu.dtype), (1,))
    xi_arr = jnp.reshape(jnp.asarray(xi, dtype=mu.dtype), (1,))
    return pl.pallas_call(
        _ei_block_kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), mu.dtype),
        interpret=True,
    )(mu, var, best_arr, xi_arr)
