"""Layer-1 Pallas kernel: tiled Matérn-5/2 cross-covariance.

Computes ``K*ᵀ[m, n] = κ(cand_m, x_train_n)`` for a batch of M candidates
against N training points, tiled ``(BM × BN)`` so each instance touches one
VMEM-resident output tile and two small operand slabs.

TPU mapping (DESIGN.md §Hardware-Adaptation): the squared distance is
expanded as ``‖a‖² + ‖b‖² − 2aᵀb`` so the inner product runs on the MXU as
a ``[BM, D] × [D, BN]`` contraction; the Matérn polynomial+exp tail is VPU
elementwise work fused onto the same tile. With BM = BN = 128 and D ≤ 8 the
tile working set is < 0.3 MiB — far under the ~16 MiB VMEM budget, so the
grid is compute-bound on the exp, not on HBM↔VMEM traffic.

Always lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md), and interpret-mode
lowering produces plain HLO that XLA fuses well.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5

# Tile sizes. 128 matches the MXU systolic-array edge; candidates and
# training points are padded to multiples of these by the caller (aot.py
# only emits bucketed shapes that divide evenly).
BM = 128
BN = 128


def _matern52_tile_kernel(cand_ref, train_ref, out_ref, *, variance, length_scale):
    """One (BM × BN) tile: distances via MXU-friendly expansion, then the
    Matérn-5/2 response."""
    a = cand_ref[...]            # [BM, D]
    b = train_ref[...]           # [BN, D]
    a_n2 = jnp.sum(a * a, axis=1, keepdims=True)        # [BM, 1]
    b_n2 = jnp.sum(b * b, axis=1, keepdims=True).T      # [1, BN]
    # MXU contraction; negative round-off clamped before the sqrt
    d2 = jnp.maximum(a_n2 + b_n2 - 2.0 * jnp.dot(a, b.T), 0.0)
    d = jnp.sqrt(d2) / length_scale
    t = SQRT5 * d
    out_ref[...] = variance * (1.0 + t + (5.0 / 3.0) * d * d) * jnp.exp(-t)


@functools.partial(jax.jit, static_argnames=("variance", "length_scale", "bm", "bn"))
def matern52_cross(cand, x_train, variance=1.0, length_scale=1.0, bm=BM, bn=BN):
    """Tiled cross-covariance ``[M, N]``; shapes must divide the tile grid
    (the AOT buckets guarantee this; tests exercise ragged shapes through
    the reference instead)."""
    m, d = cand.shape
    n, d2 = x_train.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, f"shape ({m},{n}) not tiled by ({bm},{bn})"
    kernel = functools.partial(
        _matern52_tile_kernel, variance=float(variance), length_scale=float(length_scale)
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), cand.dtype),
        interpret=True,
    )(cand, x_train)
