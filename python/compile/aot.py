"""AOT lowering: JAX/Pallas ``gp_score`` → HLO *text* artifacts for the
Rust PJRT runtime.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the ``xla`` crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

One artifact per size bucket ``(N, D)`` with a fixed candidate batch M:
the Rust runtime pads the live GP state (n ≤ N) into the bucket and
masks the padding. A JSON manifest lists every bucket for the runtime's
registry.

Usage:  python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import json
import os
import sys

import jax

# GP math is f64 end-to-end: the Rust coordinator maintains the factor in
# f64, and f32 scoring loses EI precision on the ill-conditioned covariances
# BO produces late in a run (samples cluster around the optimum). XLA CPU
# executes f64 at full speed, so the artifacts are lowered in f64.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import gp_score

# Candidate batch per scoring call; matches the Rust acquisition
# optimizer's scoring batch and the Pallas tile edge.
M = 128

# (N, D) buckets: N covers the growth of the sample set over a
# 1000-iteration run; D covers the paper's search spaces (2-D diagnostics,
# ResNet 3-D, LeNet 5-D).
BUCKETS_FULL = [
    (n, d)
    for d in (2, 3, 5)
    for n in (64, 128, 256, 512, 1024)
]
BUCKETS_QUICK = [(64, 2), (64, 5), (128, 3)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, d: int, m: int = M) -> str:
    """Lower gp_score for one (N, D) bucket to HLO text."""
    f64 = jnp.float64
    spec = lambda shape: jax.ShapeDtypeStruct(shape, f64)  # noqa: E731
    lowered = jax.jit(gp_score).lower(
        spec((n, d)),      # x_train
        spec((n, n)),      # l_factor
        spec((n,)),        # alpha
        spec((n,)),        # mask
        spec((m, d)),      # cand
        spec(()),          # best_f
        spec(()),          # xi
        spec(()),          # mean_offset
    )
    return to_hlo_text(lowered)


def artifact_name(n: int, d: int, m: int = M) -> str:
    return f"gp_score_n{n}_d{d}_m{m}.hlo.txt"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="emit only the small CI bucket set")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    buckets = BUCKETS_QUICK if args.quick else BUCKETS_FULL
    manifest = {"m": M, "buckets": [], "format": "hlo-text",
                "kernel": {"kind": "matern52", "variance": 1.0,
                           "length_scale": 1.0}}
    for n, d in buckets:
        text = lower_bucket(n, d)
        name = artifact_name(n, d)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append({"n": n, "d": d, "m": M, "file": name})
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(buckets)} artifacts + manifest to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
