"""Pallas Matérn-5/2 kernel vs the pure-jnp reference (hypothesis sweep)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.matern import matern52_cross
from compile.kernels.ref import matern52_cross_ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float64)


@pytest.mark.parametrize("m,n,d", [(128, 128, 2), (128, 256, 3), (256, 128, 5),
                                   (128, 128, 1), (384, 512, 5)])
def test_matches_reference_bucketed_shapes(m, n, d):
    rng = np.random.default_rng(m * 1000 + n + d)
    cand, xt = rand(rng, m, d), rand(rng, n, d)
    got = matern52_cross(cand, xt)
    want = matern52_cross_ref(cand, xt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(
    mt=st.integers(1, 3),  # tiles of candidates
    nt=st.integers(1, 3),  # tiles of training points
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 50.0]),
)
def test_matches_reference_hypothesis(mt, nt, d, seed, scale):
    rng = np.random.default_rng(seed)
    m, n = mt * 128, nt * 128
    cand = jnp.asarray(rng.uniform(-scale, scale, (m, d)), dtype=jnp.float64)
    xt = jnp.asarray(rng.uniform(-scale, scale, (n, d)), dtype=jnp.float64)
    got = matern52_cross(cand, xt)
    want = matern52_cross_ref(cand, xt)
    # At large separations the MXU-friendly ‖a‖²+‖b‖²−2aᵀb expansion loses
    # relative precision in f32 vs the direct (a−b)² reference — but the
    # kernel values there are ~exp(−100) ≈ 0, so absolute agreement is what
    # matters for the posterior.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=1e-5)


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(
    variance=st.floats(0.1, 10.0),
    length_scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hyperparameters_respected(variance, length_scale, seed):
    rng = np.random.default_rng(seed)
    cand, xt = rand(rng, 128, 3), rand(rng, 128, 3)
    got = matern52_cross(cand, xt, variance=variance, length_scale=length_scale)
    want = matern52_cross_ref(cand, xt, variance=variance, length_scale=length_scale)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_self_covariance_is_variance():
    rng = np.random.default_rng(0)
    x = rand(rng, 128, 4)
    k = matern52_cross(x, x, variance=2.5)
    np.testing.assert_allclose(jnp.diagonal(k), 2.5, rtol=1e-5)


def test_symmetry_on_same_inputs():
    rng = np.random.default_rng(1)
    x = rand(rng, 128, 3)
    k = np.asarray(matern52_cross(x, x))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)


def test_values_in_unit_interval_for_unit_variance():
    rng = np.random.default_rng(2)
    cand, xt = rand(rng, 128, 5), rand(rng, 256, 5)
    k = np.asarray(matern52_cross(cand, xt))
    assert (k >= 0.0).all()
    assert (k <= 1.0 + 1e-6).all()


def test_decays_with_distance():
    # move one candidate progressively farther: kernel row must decay
    xt = jnp.zeros((128, 2), dtype=jnp.float64)
    offs = jnp.linspace(0.0, 10.0, 128, dtype=jnp.float64)
    cand = jnp.stack([offs, jnp.zeros_like(offs)], axis=1)
    k = np.asarray(matern52_cross(cand, xt))[:, 0]
    assert (np.diff(k) <= 1e-7).all()


def test_f64_dtype_passthrough():
    # interpret-mode pallas should preserve f64 when given f64
    rng = np.random.default_rng(3)
    cand = jnp.asarray(rng.standard_normal((128, 3)))
    xt = jnp.asarray(rng.standard_normal((128, 3)))
    if cand.dtype == jnp.float64:  # only when x64 enabled in this env
        got = matern52_cross(cand, xt)
        assert got.dtype == cand.dtype


def test_ragged_shapes_fall_back_to_single_tile():
    # a non-multiple-of-128 M shrinks the tile to the full extent — still
    # correct, just untiled
    rng = np.random.default_rng(4)
    cand = jnp.asarray(rng.standard_normal((100, 2)), dtype=jnp.float64)
    xt = jnp.asarray(rng.standard_normal((96, 2)), dtype=jnp.float64)
    got = matern52_cross(cand, xt)
    want = matern52_cross_ref(cand, xt)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_rejects_dim_mismatch():
    with pytest.raises(AssertionError):
        matern52_cross(jnp.zeros((128, 2)), jnp.zeros((128, 3)))
