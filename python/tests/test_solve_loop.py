"""The fori_loop forward substitution (compile/model.py) vs numpy.

This loop replaces jax.scipy's solve_triangular (whose CPU lowering is a
LAPACK FFI custom-call that xla_extension 0.5.1 cannot compile), so it gets
its own correctness sweep.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.model import solve_lower_loop
from scipy_free_solve import solve_lower

jax.config.update("jax_platform_name", "cpu")


def random_lower(rng, n):
    l = np.tril(rng.uniform(-1.0, 1.0, (n, n)))
    l[np.diag_indices(n)] = rng.uniform(0.5, 2.0, n)  # well-conditioned
    return l


def test_identity_is_noop():
    b = np.arange(12, dtype=np.float64).reshape(4, 3)
    x = solve_lower_loop(jnp.eye(4), jnp.asarray(b))
    np.testing.assert_allclose(x, b, rtol=1e-14)


def test_matches_numpy_forward_substitution():
    rng = np.random.default_rng(31)
    for n, m in [(1, 1), (5, 3), (32, 8), (128, 128)]:
        l = random_lower(rng, n)
        b = rng.uniform(-2, 2, (n, m))
        got = solve_lower_loop(jnp.asarray(l), jnp.asarray(b))
        want = solve_lower(l, b)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(
    n=st.integers(1, 48),
    m=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_numpy_hypothesis(n, m, seed):
    rng = np.random.default_rng(seed)
    l = random_lower(rng, n)
    b = rng.uniform(-3, 3, (n, m))
    got = solve_lower_loop(jnp.asarray(l), jnp.asarray(b))
    want = solve_lower(l, b)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-11)


def test_residual_is_tiny():
    rng = np.random.default_rng(37)
    n, m = 64, 7
    l = random_lower(rng, n)
    b = rng.uniform(-1, 1, (n, m))
    x = np.asarray(solve_lower_loop(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(l @ x, b, rtol=1e-9, atol=1e-11)


def test_jit_matches_eager():
    rng = np.random.default_rng(41)
    l = jnp.asarray(random_lower(rng, 24))
    b = jnp.asarray(rng.uniform(-1, 1, (24, 4)))
    np.testing.assert_allclose(
        jax.jit(solve_lower_loop)(l, b), solve_lower_loop(l, b), rtol=1e-14
    )
