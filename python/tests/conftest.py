"""Make the build-time package importable when pytest runs from python/."""

import os
import sys

# The build-time stack is f64 end-to-end (the AOT artifacts are lowered in
# f64 — see compile/aot.py); enable x64 before jax initializes anywhere.
import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
