"""Pallas EI kernel vs the pure-jnp reference."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ei import expected_improvement
from compile.kernels.ref import ei_ref

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("m", [128, 256, 512])
def test_matches_reference(m):
    rng = np.random.default_rng(m)
    mu = jnp.asarray(rng.uniform(-3, 3, m), dtype=jnp.float64)
    var = jnp.asarray(rng.uniform(0, 4, m), dtype=jnp.float64)
    got = expected_improvement(mu, var, 0.5, 0.01)
    want = ei_ref(mu, var, 0.5, 0.01)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-6)


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(
    best_f=st.floats(-10.0, 10.0),
    xi=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_hypothesis(best_f, xi, seed):
    rng = np.random.default_rng(seed)
    mu = jnp.asarray(rng.uniform(-12, 12, 128), dtype=jnp.float64)
    var = jnp.asarray(rng.uniform(0, 9, 128), dtype=jnp.float64)
    got = expected_improvement(mu, var, best_f, xi)
    want = ei_ref(mu, var, best_f, xi)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-6)


def test_zero_variance_gives_zero_ei():
    mu = jnp.linspace(-2, 2, 128, dtype=jnp.float64)
    var = jnp.zeros(128, dtype=jnp.float64)
    got = np.asarray(expected_improvement(mu, var, 0.0, 0.0))
    np.testing.assert_array_equal(got, np.zeros(128))


def test_nonnegative():
    rng = np.random.default_rng(7)
    mu = jnp.asarray(rng.uniform(-100, 100, 256), dtype=jnp.float64)
    var = jnp.asarray(rng.uniform(0, 100, 256), dtype=jnp.float64)
    got = np.asarray(expected_improvement(mu, var, 50.0, 0.1))
    assert (got >= 0.0).all()


def test_monotone_in_mean():
    mu = jnp.linspace(-5, 5, 128, dtype=jnp.float64)
    var = jnp.full(128, 1.0, dtype=jnp.float64)
    got = np.asarray(expected_improvement(mu, var, 0.0, 0.0))
    assert (np.diff(got) >= -1e-6).all()


def test_far_above_incumbent_tends_to_gamma():
    # for μ ≫ f', EI → γ = μ − f' − ξ
    mu = jnp.full(128, 100.0, dtype=jnp.float64)
    var = jnp.full(128, 1.0, dtype=jnp.float64)
    got = np.asarray(expected_improvement(mu, var, 0.0, 0.0))
    np.testing.assert_allclose(got, 100.0, rtol=1e-5)


def test_ragged_length_falls_back_to_single_block():
    rng = np.random.default_rng(9)
    mu = jnp.asarray(rng.uniform(-3, 3, 100), dtype=jnp.float64)
    var = jnp.asarray(rng.uniform(0, 4, 100), dtype=jnp.float64)
    got = expected_improvement(mu, var, 0.25, 0.01)
    want = ei_ref(mu, var, 0.25, 0.01)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-6)
