"""AOT lowering smoke tests: the HLO-text bridge the Rust runtime consumes."""

import json
import os
import subprocess
import sys

import pytest

from compile.aot import artifact_name, lower_bucket, BUCKETS_QUICK


def test_lower_bucket_emits_hlo_text():
    text = lower_bucket(64, 2)
    assert text.startswith("HloModule")
    # the triangular solve of Alg. 1 line 5 lowers to a while loop (no
    # lapack custom-call — xla_extension 0.5.1 can't compile TYPED_FFI)
    assert "while" in text
    assert "custom-call" not in text
    # the EI tail must NOT use the erf opcode (xla_extension 0.5.1's text
    # parser rejects it) — the kernel expands erf to mul/add/exp instead
    assert " erf(" not in text
    assert "exponential" in text or "exp" in text


def test_artifact_names_stable():
    assert artifact_name(256, 5) == "gp_score_n256_d5_m128.hlo.txt"


@pytest.mark.parametrize("n,d", BUCKETS_QUICK)
def test_quick_buckets_lower(n, d):
    text = lower_bucket(n, d)
    assert len(text) > 1000
    # static shapes visible in the module signature
    assert f"f64[{n},{d}]" in text
    assert f"f64[{n},{n}]" in text


def test_cli_quick_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=repo_python,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["m"] == 128
    assert len(manifest["buckets"]) == len(BUCKETS_QUICK)
    for b in manifest["buckets"]:
        assert (out / b["file"]).exists()
        assert (out / b["file"]).read_text().startswith("HloModule")
