"""L2 gp_score vs the reference and vs a from-scratch numpy GP, incl. the
padding/masking contract the Rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import gp_score_ref, matern52_cross_ref
from compile.model import gp_score

jax.config.update("jax_platform_name", "cpu")

N, M, D = 128, 128, 3


def make_gp_state(rng, n_live, n_bucket, d):
    """Build a live GP state (numpy, f64) and its padded f32 bucket form."""
    x = rng.uniform(-2, 2, (n_live, d))
    y = np.sin(x.sum(axis=1))
    k = np.array(matern52_cross_ref(jnp.asarray(x), jnp.asarray(x)), dtype=np.float64)
    k[np.diag_indices_from(k)] += 1e-6
    l = np.linalg.cholesky(k)
    offset = y.mean()
    alpha = np.linalg.solve(k, y - offset)

    # pad into the bucket: unit-diagonal L rows, zero alpha, zero mask
    xp = np.zeros((n_bucket, d))
    xp[:n_live] = x
    lp = np.eye(n_bucket)
    lp[:n_live, :n_live] = l
    ap = np.zeros(n_bucket)
    ap[:n_live] = alpha
    mask = np.zeros(n_bucket)
    mask[:n_live] = 1.0
    f32 = lambda a: jnp.asarray(a, dtype=jnp.float64)  # noqa: E731
    return (x, l, alpha, offset), (f32(xp), f32(lp), f32(ap), f32(mask))


def test_matches_reference_full_bucket():
    rng = np.random.default_rng(11)
    (_, _, _, offset), (xp, lp, ap, mask) = make_gp_state(rng, N, N, D)
    cand = jnp.asarray(rng.uniform(-2, 2, (M, D)), dtype=jnp.float64)
    got = gp_score(xp, lp, ap, mask, cand, 0.8, 0.01, offset)
    want = gp_score_ref(xp, lp, ap, mask, cand, 0.8, 0.01, offset)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n_live", [1, 7, 40, 127])
def test_padding_is_inert(n_live):
    """Scoring a padded state must equal scoring the unpadded state."""
    rng = np.random.default_rng(100 + n_live)
    (x, l, alpha, offset), (xp, lp, ap, mask) = make_gp_state(rng, n_live, N, D)
    cand_np = rng.uniform(-2, 2, (M, D))
    cand = jnp.asarray(cand_np, dtype=jnp.float64)

    mu_pad, var_pad, ei_pad = gp_score(xp, lp, ap, mask, cand, 0.5, 0.01, offset)

    # exact (f64, numpy) posterior on the live state
    ks = np.asarray(
        matern52_cross_ref(jnp.asarray(cand_np), jnp.asarray(x)), dtype=np.float64
    )
    mu_true = ks @ alpha + offset
    from scipy_free_solve import solve_lower  # local helper below

    v = solve_lower(l, ks.T)
    var_true = np.maximum(1.0 - (v * v).sum(axis=0), 0.0)

    np.testing.assert_allclose(mu_pad, mu_true, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(var_pad, var_true, rtol=2e-3, atol=2e-3)
    assert np.asarray(ei_pad).min() >= 0.0


def test_variance_at_training_points_near_zero():
    rng = np.random.default_rng(13)
    (x, _, _, offset), (xp, lp, ap, mask) = make_gp_state(rng, 32, N, D)
    cand = jnp.asarray(np.vstack([x[:16], rng.uniform(5, 6, (M - 16, D))]),
                       dtype=jnp.float64)
    _, var, _ = gp_score(xp, lp, ap, mask, cand, 0.0, 0.01, offset)
    var = np.asarray(var)
    assert (var[:16] < 1e-2).all(), var[:16]
    assert (var[16:] > 0.5).all()  # far from data ⇒ near prior variance


def test_mean_far_away_returns_prior_offset():
    rng = np.random.default_rng(17)
    (_, _, _, offset), (xp, lp, ap, mask) = make_gp_state(rng, 32, N, D)
    cand = jnp.asarray(rng.uniform(50, 60, (M, D)), dtype=jnp.float64)
    mu, var, _ = gp_score(xp, lp, ap, mask, cand, 0.0, 0.01, offset)
    np.testing.assert_allclose(mu, offset, atol=1e-3)
    np.testing.assert_allclose(var, 1.0, atol=1e-3)


def test_jit_and_eager_agree():
    rng = np.random.default_rng(19)
    (_, _, _, offset), (xp, lp, ap, mask) = make_gp_state(rng, 64, N, D)
    cand = jnp.asarray(rng.uniform(-2, 2, (M, D)), dtype=jnp.float64)
    eager = gp_score(xp, lp, ap, mask, cand, 0.3, 0.01, offset)
    jitted = jax.jit(gp_score)(xp, lp, ap, mask, cand, 0.3, 0.01, offset)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(e, j, rtol=1e-5, atol=1e-6)
