"""Tiny numpy forward-substitution helper (scipy is not a dependency)."""

import numpy as np


def solve_lower(l, b):
    """Solve ``L X = B`` for lower-triangular ``L`` (multi-RHS), f64."""
    l = np.asarray(l, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = l.shape[0]
    x = b.copy()
    for i in range(n):
        x[i] -= l[i, :i] @ x[:i]
        x[i] /= l[i, i]
    return x
